"""Silent-data-corruption self-healing (DESIGN.md §12): background
integrity scrubbing, end-to-end wire verification, and
quarantine-and-repair over the fused wire.

The invariants under test:
  * **One fold** — host ``row_checksum`` and device
    ``row_checksum_device`` agree bit for bit over every wire dtype, and
    the fold itself is PINNED (hard-coded expected words): on-wire
    checksums must survive refactors, because stamps of old payloads in
    flight verify against new code during a rolling upgrade;
  * **Detection within the scrub window** — an injected bit flip in a
    resident row, a hot-cache copy, or a wire segment is detected within
    ``ceil(total_blocks / budget)`` flushes, on both exchange pipelines;
  * **Bit-exact repair** — repaired tables equal the uncorrupted oracle
    engine's byte for byte, with zero requests lost, and a repair never
    resurrects a value a fresher delta overwrote;
  * **Zero extra collectives** — the repair rider and the wire checksum
    ride the SAME fused buffer: one all_to_all (mono) / P−1 ppermutes
    (ring) in the jaxpr, scrub or no scrub;
  * **Honesty with the mirror off** — detection and quarantine still
    work (checksum shadow), repair does not: quarantined rows serve the
    degraded fallback until a delta overwrites them.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np

from repro.core.integrity import (IntegrityLedger, row_checksum,
                                  row_checksum_device, wire_stamp,
                                  wire_verify)
from repro.serving.hot_cache import HotCache, build, invalidate

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# One fold: host/device equivalence + pinned values (satellite: dedup)
# ---------------------------------------------------------------------------


class TestFoldEquivalence:
    def test_host_equals_device_across_dtypes(self):
        """The deduplicated fold: freshness (dcs), reshard (mcs) and
        scrub/repair (rcs) all stamp with row_checksum and verify with
        either side — host and device must agree over every dtype the
        wire carries."""
        import jax.numpy as jnp
        rng = np.random.default_rng(7)
        for dt in (jnp.float32, jnp.bfloat16, jnp.float16):
            vecs = jnp.asarray(
                rng.standard_normal((6, 8)), jnp.float32).astype(dt)
            gids = np.arange(6) * 13 + 2
            host = row_checksum(np.asarray(vecs), gids, 3)
            dev = np.asarray(jax.device_get(row_checksum_device(
                vecs, jnp.asarray(gids, jnp.int32), jnp.int32(3))))
            assert np.array_equal(host, dev), dt

    def test_fold_is_pinned(self):
        """Hard-coded expected words: changing the weight schedule, the
        mixing constants, or the wrap silently breaks every stamp already
        on the wire — this test makes that loud."""
        vec = np.arange(8, dtype=np.float32)
        assert int(row_checksum(vec, 0, 0)) == 29048
        assert int(row_checksum(vec, 123, 7)) == 1479294494
        z = np.zeros(4, np.float32)
        assert int(row_checksum(z, 1, 0)) == 2654435761

    def test_freshness_and_reshard_reexports_are_the_same_function(self):
        from repro.core import integrity
        from repro.runtime import freshness
        assert freshness.row_checksum is integrity.row_checksum


# ---------------------------------------------------------------------------
# IntegrityLedger: blocked sums + O(1) incremental refold
# ---------------------------------------------------------------------------


class TestIntegrityLedger:
    def test_note_update_matches_full_recompute(self):
        rng = np.random.default_rng(3)
        tables = rng.standard_normal((4, 20, 8)).astype(np.float32)
        led = IntegrityLedger.from_tables(tables, block_rows=8)
        # overwrite a handful of rows, refolding incrementally
        for gid in (0, 19, 21, 45, 79):
            t, r = divmod(gid, 20)
            new = rng.standard_normal(8).astype(np.float32)
            led.note_update(gid, tables[t, r], new)
            tables[t, r] = new
        want = IntegrityLedger.from_tables(tables, block_rows=8)
        assert np.array_equal(led.block_cs, want.block_cs)

    def test_single_bit_flip_moves_exactly_one_block(self):
        rng = np.random.default_rng(4)
        tables = rng.standard_normal((2, 16, 4)).astype(np.float32)
        led = IntegrityLedger.from_tables(tables, block_rows=4)
        mut = tables.copy()
        mut[1, 9].view(np.uint8)[2] ^= 0x10
        got = IntegrityLedger.from_tables(mut, block_rows=4)
        diff = led.block_cs != got.block_cs
        assert diff.sum() == 1 and diff[1, 9 // 4]

    def test_padding_rows_fold_to_zero(self):
        """Blocks past R must not contribute: a ledger over (t_pad, R)
        with R not a block multiple still matches a device fold whose
        padding offsets are masked."""
        tables = np.ones((1, 10, 4), np.float32)
        led = IntegrityLedger.from_tables(tables, block_rows=4)
        assert led.n_blocks == 3
        # last block covers rows 8..9 only
        rcs = row_checksum(tables[0, 8:10],
                           np.arange(8, 10), 0).astype(np.uint64)
        assert int(led.block_cs[0, 2]) == int(rcs.sum() % (1 << 32))


# ---------------------------------------------------------------------------
# Wire stamp/verify: the end-to-end serving-payload checksum
# ---------------------------------------------------------------------------


class TestWireStampVerify:
    def _layout(self):
        import jax.numpy as jnp
        from repro.core.alltoallv import wire_layout
        return wire_layout(3, {"emb": ((24,), jnp.uint8),
                               "wcs": ((1,), jnp.uint32)})

    def test_stamp_then_verify_and_any_flip_rejects(self):
        import jax.numpy as jnp
        layout = self._layout()
        rng = np.random.default_rng(5)
        buf = jnp.asarray(rng.integers(0, 256, (3, layout.slot_bytes)),
                          jnp.uint8)
        stamped = wire_stamp(buf, layout)
        assert bool(np.all(np.asarray(wire_verify(stamped, layout))))
        f = layout.field("wcs")
        payload = [i for i in range(layout.slot_bytes)
                   if not (f.offset <= i < f.offset + 4)]
        for i in payload:
            mut = stamped.at[1, i].set(stamped[1, i] ^ 1)
            ok = np.asarray(wire_verify(mut, layout))
            assert not ok[1] and ok[0] and ok[2], i

    def test_stamp_does_not_perturb_what_it_protects(self):
        """Stamping twice is a fixpoint: the wcs bytes are zero-weighted,
        so writing the stamp does not change the fold it records."""
        import jax.numpy as jnp
        layout = self._layout()
        buf = jnp.asarray(np.arange(3 * layout.slot_bytes).reshape(3, -1)
                          % 251, jnp.uint8)
        once = wire_stamp(buf, layout)
        twice = wire_stamp(once, layout)
        assert np.array_equal(np.asarray(once), np.asarray(twice))


# ---------------------------------------------------------------------------
# Hot-cache invalidate: range guard + parity vs rebuild (satellite fix)
# ---------------------------------------------------------------------------


class TestInvalidateRangeGuard:
    def _cache(self):
        import jax.numpy as jnp
        rng = np.random.default_rng(6)
        tables = jnp.asarray(rng.standard_normal((3, 12, 4)), jnp.float32)
        counts = rng.integers(0, 50, (3, 12))
        return tables, build(tables, counts, 4)

    def test_oob_entries_are_dropped_not_wrapped(self):
        """The bug this pins: an OOB-high (bucket-padding sentinel) or
        negative (tab, row) used to WRAP under jnp gather indexing, read
        some other row's slot, and clobber it."""
        tables, cache = self._cache()
        t_all, r_all = cache.slot_of.shape
        tab = np.array([t_all, -1, 0, t_all + 5], np.int32)
        row = np.array([0, 3, r_all + 2, -7], np.int32)
        out, n = invalidate(cache, tab, row)
        assert n == 0
        assert np.array_equal(np.asarray(out.slot_of),
                              np.asarray(cache.slot_of))
        assert np.array_equal(np.asarray(out.hot_rows),
                              np.asarray(cache.hot_rows))
        assert np.array_equal(np.asarray(out.hot_ids),
                              np.asarray(cache.hot_ids))

    def test_parity_with_full_rebuild(self):
        """Invalidating rows one by one must leave exactly the slots a
        from-scratch build WITHOUT those rows would leave live (bit
        parity on the surviving cached vectors, mirroring the
        refresh_rows parity test of PR 8)."""
        tables, cache = self._cache()
        kill = [(0, int(np.asarray(cache.hot_ids)[0, 1])),
                (2, int(np.asarray(cache.hot_ids)[2, 0]))]
        tab = np.array([t for t, _ in kill], np.int32)
        row = np.array([r for _, r in kill], np.int32)
        out, n = invalidate(cache, tab, row)
        assert n == 2
        slot_of = np.asarray(out.slot_of)
        ids = np.asarray(out.hot_ids)
        rows = np.asarray(out.hot_rows)
        th = np.asarray(tables)
        for t, r in kill:
            assert slot_of[t, r] == -1
        for t in range(slot_of.shape[0]):
            for r in range(slot_of.shape[1]):
                s = slot_of[t, r]
                if s >= 0:
                    assert ids[t, s] == r
                    assert np.array_equal(rows[t, s], th[t, r])


# ---------------------------------------------------------------------------
# ServeStats: JSON round-trip of the full ledger (satellite: coverage)
# ---------------------------------------------------------------------------


class TestServeStatsRoundTrip:
    def test_to_dict_json_roundtrips_every_counter(self):
        from repro.serving.engine import ServeStats
        st = ServeStats()
        st.requests = 7
        st.blocks_scrubbed = 40
        st.detections = 3
        st.repaired_rows = 2
        st.quarantined_served = 5
        st.wire_rejects = 1
        st.detection_lag_flushes = 4
        d = st.to_dict()
        for k in ("requests", "batches", "replays", "evictions",
                  "recovery_s", "approx_rows", "rows_applied",
                  "delta_rejects", "apply_rollbacks", "versions_behind",
                  "rows_stale_served", "reshards", "migrated_rows",
                  "blocks_scrubbed", "detections", "repaired_rows",
                  "quarantined_served", "wire_rejects",
                  "detection_lag_flushes"):
            assert k in d, k
        back = json.loads(json.dumps(d))
        assert back["blocks_scrubbed"] == 40
        assert back["detections"] == 3
        assert back["repaired_rows"] == 2
        assert back["quarantined_served"] == 5
        assert back["wire_rejects"] == 1
        assert back["detection_lag_flushes"] == 4
        assert back == json.loads(json.dumps(st.to_dict()))


# ---------------------------------------------------------------------------
# End-to-end: the serving engine under injected corruption
# ---------------------------------------------------------------------------


_PREAMBLE = """
import itertools
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import DLRMConfig
from repro.models import dlrm as D
from repro.sharding import partition
from repro.data import synthetic as S
from repro.runtime import elastic
from repro.runtime.faults import FaultPlan, FaultInjector
from repro.serving.engine import DLRMEngine

cfg = DLRMConfig('t', table_sizes=(40, 60, 30, 50, 20, 70), embed_dim=8,
                 n_dense_features=4, bottom_mlp=(16, 8), top_mlp=(16, 1),
                 sparse_backend='ref')
P, B = 4, 48
mesh = elastic.make_mesh_from(jax.devices()[:P], model=P)
params = D.init_dlrm(jax.random.PRNGKey(0), cfg, n_shards=P)
t_pad = D.padded_tables(cfg, P)
batches = [S.make_batch(cfg, B, mode='powerlaw', t_pad=t_pad, seed=9,
                        step=s) for s in range(12)]
oracle = np.array(jax.device_get(params['tables']))


def run_serve(faults=None, n_flushes=14, calibrate=False, **eng_kw):
    eng = DLRMEngine(params, cfg, batch_size=B, bound=1, microbatches=2,
                     exchange='dense', faults=faults, retry_backoff_s=0.0,
                     scrub_budget=eng_kw.pop('scrub_budget', 8), **eng_kw)
    outs = []
    with partition.axis_rules(mesh):
        if calibrate:
            b0 = batches[0]
            eng.calibrate_cache(b0.idx, b0.mask, cache_rows=8)
        for s in range(n_flushes):
            b = batches[s % len(batches)]
            for r in range(B):
                o = eng.submit(b.dense[r], b.idx[r], b.mask[r])
                if o is not None:
                    outs.append(np.asarray(o))
    return eng, outs


def check_tables(eng):
    # per-table compare: survives post-evict geometry (t_pad shrinks)
    got = np.array(jax.device_get(eng.params['tables']))
    for t, size in enumerate(cfg.table_sizes):
        assert np.array_equal(oracle[t, :size], got[t, :size]), \\
            f'table {t} diverged from oracle'
"""


def test_clean_path_bit_exact_with_scrub_armed():
    """Scrub on, no faults: identical CTRs to a no-scrub engine, blocks
    audited every flush, zero detections, zero wire rejects — the whole
    verification apparatus is value-neutral when nothing is wrong."""
    run_sub(_PREAMBLE + """
eng0 = DLRMEngine(params, cfg, batch_size=B, bound=1, microbatches=2,
                  exchange='dense')
eng, outs = run_serve(n_flushes=6)
outs0 = []
with partition.axis_rules(mesh):
    for s in range(6):
        b = batches[s % len(batches)]
        for r in range(B):
            o = eng0.submit(b.dense[r], b.idx[r], b.mask[r])
            if o is not None:
                outs0.append(np.asarray(o))
for a, b_ in zip(outs0, outs):
    assert np.array_equal(a, b_)
st = eng.stats
assert st.blocks_scrubbed > 0
assert st.detections == 0 and st.wire_rejects == 0
assert st.repaired_rows == 0 and st.quarantined_served == 0
check_tables(eng)
print('ok')
""")


def test_bitflip_grid_detected_and_repaired_bit_exact():
    """The acceptance grid: a resident-row flip and a hot-cache flip, on
    both exchange pipelines, under f32 and bf16 wire dtypes — each
    detected within the scrub window, resident flips repaired bit-exact
    vs the uncorrupted oracle, zero requests lost."""
    run_sub(_PREAMBLE + """
from repro.serving import hot_cache as HC
# the cache leg must flip a row that IS cached: precompute the cache the
# engine will calibrate (deterministic from tables + batch 0)
pre = HC.build_from_batch(params['tables'], batches[0].idx,
                          batches[0].mask, 8)
crow = int(np.asarray(pre.hot_ids)[2, 0])
for pipe in ('mono', 'ring'):
    for wire in ('f32', 'bf16'):
        for target in ('table', 'cache'):
            row = 7 if target == 'table' else crow
            plan = FaultPlan.none(P, 40).with_bitflip(
                1, 2, row, 5, when=2, target=target)
            eng, outs = run_serve(faults=FaultInjector(plan),
                                  exchange_pipeline=pipe, wire_dtype=wire,
                                  calibrate=(target == 'cache'),
                                  n_flushes=14)
            st = eng.stats
            tag = (pipe, wire, target)
            assert len(outs) == 14, (tag, len(outs))      # zero lost
            assert st.detections >= 1, tag
            # scrub window with budget 8: blocks = 8 tables x 3 blocks
            # -> 3 flushes; cache slots = 8 x 8 -> 8 flushes
            lim = 4 if target == 'table' else 9
            assert st.detection_lag_flushes <= lim, (tag, st)
            if target == 'table':
                assert st.repaired_rows >= 1, tag
                assert eng.scrub.fully_repaired, tag
                check_tables(eng)
            else:
                # a corrupt CACHED copy invalidates (base row was never
                # wrong): tables still pristine, slot now a miss
                check_tables(eng)
                assert eng.scrub.cache_invalidations >= 1, tag
                sl = np.asarray(jax.device_get(eng.cache.slot_of))
                assert sl[2, crow] == -1, tag
print('ok')
""")


def test_wire_corruption_rejected_and_reshipped_zero_lost():
    """A corrupted serving segment is detected at consume on BOTH
    pipelines: the segment's contribution zeroes (finite outputs, no
    poisoned unpack), wire_rejects ledgers it, and serving + repair
    continue to bit-exact convergence."""
    run_sub(_PREAMBLE + """
for pipe in ('mono', 'ring'):
    plan = (FaultPlan.none(P, 40)
            .with_wire_corruption(2, 0, when=3)
            .with_bitflip(1, 2, 7, 5, when=2))
    eng, outs = run_serve(faults=FaultInjector(plan),
                          exchange_pipeline=pipe, n_flushes=14)
    st = eng.stats
    assert len(outs) == 14, (pipe, len(outs))
    assert st.wire_rejects >= 1, pipe
    assert all(np.isfinite(o).all() for o in outs), pipe
    assert st.repaired_rows >= 1, pipe
    check_tables(eng)
print('ok')
""")


def test_persistent_wire_corruption_escalates_degrade_then_evict():
    """One link corrupting EVERY flush walks the ladder: streak >=
    confirm_after degrades the source, >= 2x evicts it — and every
    request is still answered (the reject path zeroes, never drops)."""
    run_sub(_PREAMBLE + """
plan = FaultPlan.none(P, 60)
for s in range(2, 30):
    plan = plan.with_wire_corruption(2, 0, when=s)
eng, outs = run_serve(faults=FaultInjector(plan), n_flushes=16,
                      confirm_after=2)
st = eng.stats
assert len(outs) == 16
assert st.wire_rejects >= 4
assert st.evictions >= 1, st.evictions      # ladder completed
assert all(np.isfinite(o).all() for o in outs)
print('ok')
""")


def test_mirror_disabled_detects_and_quarantines_but_cannot_repair():
    """The honesty gap, asserted: with scrub_mirror=False the checksum
    shadow still detects at row granularity and quarantines (corrupt
    rows serve the degraded zero fallback, ledgered in
    quarantined_served), but repaired_rows stays 0 and the corruption
    persists until an authorized delta overwrites it."""
    run_sub(_PREAMBLE + """
# flip a row every batch actually touches so quarantined_served counts
hot = None
for t in range(6):
    for r0 in range(cfg.table_sizes[t]):
        if all(((b.idx[:, t] == r0) & (b.mask[:, t] > 0)).any()
               for b in batches[:6]):
            hot = (t, r0)
            break
    if hot:
        break
assert hot is not None
plan = FaultPlan.none(P, 40).with_bitflip(0, hot[0], hot[1], 3, when=2)
eng, outs = run_serve(faults=FaultInjector(plan), scrub_mirror=False,
                      n_flushes=12)
st = eng.stats
assert len(outs) == 12
assert st.detections >= 1
assert st.repaired_rows == 0                   # cannot repair
assert len(eng.scrub.quarantined) == 1         # still quarantined
assert st.quarantined_served > 0               # served degraded, visibly
assert all(np.isfinite(o).all() for o in outs)
got = np.array(jax.device_get(eng.params['tables']))
t0 = hot[0]
assert not np.array_equal(got[t0, :cfg.table_sizes[t0]],
                          oracle[t0, :cfg.table_sizes[t0]])  # persists
print('ok')
""")


def test_repair_never_resurrects_a_fresher_delta():
    """Interop with PR 8: a row is flipped AND later overwritten by an
    online delta.  The delta must win — the final bytes are the delta's,
    not the pre-flip mirror's — and the quarantine lifts without a
    repair ever landing on that row."""
    run_sub(_PREAMBLE + """
from repro.runtime.freshness import FreshnessManager, oracle_tables
N_VER = 4
delta_batches = [S.make_delta_batch(cfg, v, rows_per_version=6, seed=3)
                 for v in range(1, N_VER + 1)]
src = itertools.islice(S.delta_stream(cfg, rows_per_version=6, seed=3),
                       N_VER)
# flip a row that version 2 of the stream will overwrite
tgt = (int(delta_batches[1].tab[0]), int(delta_batches[1].row[0]))
plan = FaultPlan.none(P, 40).with_bitflip(0, tgt[0], tgt[1], 9, when=1)
fm = FreshnessManager(src, k_fresh=2, slice_cap=4, versions_per_flush=1)
eng, outs = run_serve(faults=FaultInjector(plan), freshness=fm,
                      n_flushes=16)
assert fm.fully_committed
assert eng.scrub.fully_repaired
want = np.array(jax.device_get(
    oracle_tables(params['tables'], delta_batches)))
got = np.array(jax.device_get(eng.params['tables']))
for t, size in enumerate(cfg.table_sizes):
    assert np.array_equal(want[t, :size], got[t, :size]), t
print('ok')
""")


def test_scrub_riders_add_zero_collectives_in_jaxpr():
    """The wire contract, asserted from the jaxpr: WITH the repair rider
    ("xrep"), the wire checksum ("wcs"), the quarantine mask and the
    flip hook all aboard, a mono step still lowers to exactly one
    all_to_all and a ring step to exactly P−1 ppermutes."""
    run_sub("""
import collections
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs.base import DLRMConfig
from repro.models import dlrm as D
from repro.data import synthetic as S
from repro.sharding import partition

def count_collectives(closed):
    c = collections.Counter()
    def walk(jx):
        for eqn in jx.eqns:
            c[eqn.primitive.name] += 1
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (tuple, list)) else [v]):
                    if hasattr(sub, "jaxpr"):
                        walk(sub.jaxpr)
                    elif hasattr(sub, "eqns"):
                        walk(sub)
    walk(closed.jaxpr)
    return c

cfg = DLRMConfig(name='t', table_sizes=(100, 50, 80, 60, 90, 40),
                 embed_dim=16, bottom_mlp=(32, 16), top_mlp=(32, 1),
                 max_hot=4)
mesh = compat.make_mesh((2, 4), ("data", "model"))
params = D.init_dlrm(jax.random.PRNGKey(0), cfg, n_shards=4)
b = S.make_batch(cfg, 64, mode='hetero', t_pad=D.padded_tables(cfg, 4),
                 seed=1)
dense, idx, mask = map(jnp.asarray, (b.dense, b.idx, b.mask))
P, mb, rcap, s = 4, 2, 4, 16
repair = {
    'rcnt': jnp.zeros((P, mb, 1), jnp.int32),
    'rcs': jnp.zeros((P, mb, rcap), jnp.uint32),
    'rgid': jnp.zeros((P, mb, rcap), jnp.int32),
    'rvec': jnp.zeros((P, mb, rcap, s), jnp.float32),
}
quar = jnp.full((16,), -1, jnp.int32)
flip = jnp.zeros((P, P), jnp.uint8)
with partition.axis_rules(mesh):
    for pipe, want in [('mono', (1, 0)), ('ring', (0, 3))]:
        for armed in (False, True):
            kw = dict(repair=repair, quarantine=quar, wire_flip=flip,
                      wire_check=True) if armed else {}
            jx = jax.make_jaxpr(
                lambda p, d, i, m, pipe=pipe, kw=kw:
                D.forward_distributed(p, cfg, d, i, m, microbatches=mb,
                                      exchange='dense',
                                      exchange_pipeline=pipe, **kw)
                )(params, dense, idx, mask)
            c = count_collectives(jx)
            got = (c['all_to_all'], c['ppermute'])
            assert got == want, (pipe, armed, dict(c))
print('ok')
""")


def test_repaired_base_row_leaves_no_stale_cache_copy():
    """Satellite-3 coherence, end to end: corrupt the BASE copy of a row
    whose clean copy sits in the hot cache.  Whatever order the block
    audit and the cache audit find it in, after repair there is no
    window where a lookup could see stale bytes: the slot either still
    holds a copy bit-equal to the repaired base (refreshed in the SAME
    commit) or was invalidated to a miss (base authoritative)."""
    run_sub(_PREAMBLE + """
from repro.serving import hot_cache as HC
pre = HC.build_from_batch(params['tables'], batches[0].idx,
                          batches[0].mask, 8)
crow = int(np.asarray(pre.hot_ids)[2, 0])
plan = FaultPlan.none(P, 40).with_bitflip(1, 2, crow, 5, when=2,
                                          target='table')
eng, outs = run_serve(faults=FaultInjector(plan), calibrate=True,
                      n_flushes=14)
st = eng.stats
assert len(outs) == 14
assert st.repaired_rows >= 1 and eng.scrub.fully_repaired
check_tables(eng)
sl = np.asarray(jax.device_get(eng.cache.slot_of))
slot = int(sl[2, crow])
if slot >= 0:
    cc = np.asarray(jax.device_get(eng.cache.hot_rows))[2, slot]
    base = np.asarray(jax.device_get(eng.params['tables']))[2, crow]
    assert np.array_equal(cc, base), 'stale cached copy after repair'
print('ok')
""")


def test_scrub_survives_eviction_and_keeps_repairing():
    """Crash recovery interop (PR 6): a member dies mid-serve while a
    flip is still unrepaired.  The scrubber refits to the shrunken
    geometry WITHOUT re-blessing the on-device corruption, re-queues the
    repair, and converges bit-exact on the survivors."""
    run_sub(_PREAMBLE + """
plan = (FaultPlan.none(P, 40)
        .with_bitflip(1, 2, 7, 5, when=2)
        .with_crash(3, 4))
eng, outs = run_serve(faults=FaultInjector(plan), n_flushes=14)
st = eng.stats
assert st.evictions == 1
assert len(outs) == 14                      # zero lost through the crash
assert st.repaired_rows >= 1
assert eng.scrub.fully_repaired
check_tables(eng)
print('ok')
""")
