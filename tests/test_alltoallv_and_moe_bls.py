"""alltoallv machinery + the BLS×MoE composition (the paper's collective
decoupling applied to expert-parallel dispatch)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.alltoallv import dispatch_stats, pack_ragged

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestPackRagged:
    def test_roundtrip_and_counts(self):
        rows = jnp.arange(24.0).reshape(12, 2)
        dest = jnp.asarray([0, 0, 1, 2, 2, 2, 3, 3, 3, 3, 0, 1])
        buf, counts, drops = pack_ragged(rows, dest, n_dest=4, cap=8)
        assert counts.tolist() == [3, 2, 3, 4]
        assert int(drops) == 0
        # every valid row lands in its destination bucket
        for d in range(4):
            want = np.asarray(rows)[np.asarray(dest) == d]
            got = np.asarray(buf[d][: int(counts[d])])
            assert np.allclose(np.sort(got, 0), np.sort(want, 0)), d

    def test_capacity_drop(self):
        rows = jnp.ones((10, 2))
        dest = jnp.zeros((10,), jnp.int32)
        buf, counts, drops = pack_ragged(rows, dest, n_dest=2, cap=4)
        assert int(counts[0]) == 4  # 6 dropped (static-shape price)
        assert int(counts[1]) == 0
        assert int(drops) == 6     # ... and the pack says so

    def test_excluded_rows_are_not_drops(self):
        # dest -1 marks dead rows (the ragged exchange's all-hit bags):
        # excluded by design, never reported as drops
        rows = jnp.ones((6, 2))
        dest = jnp.asarray([-1, 0, -1, 1, -1, 1], jnp.int32)
        _, counts, drops = pack_ragged(rows, dest, n_dest=2, cap=4)
        assert counts.tolist() == [1, 2]
        assert int(drops) == 0

    def test_dispatch_stats(self):
        counts = jnp.asarray([3, 2, 3, 4])
        st = dispatch_stats(counts, cap=8, row_bytes=16)
        assert st.useful_bytes == 12 * 16
        assert st.payload_bytes == 32 * 16
        assert st.padding_fraction == pytest.approx(1 - 12 / 32)


def run_sub(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


def test_alltoallv_raw_roundtrip_multidevice():
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.alltoallv import alltoallv_raw, pack_ragged
from repro import compat
mesh = compat.make_mesh((8,), ("model",))

def shard_fn(rows, dest):
    buf, counts, _ = pack_ragged(rows, dest, n_dest=8, cap=16)
    recv, rcounts = alltoallv_raw(buf, counts, "model")
    # checksum of valid rows survives the exchange globally
    mask = jnp.arange(16)[None, :] < rcounts[:, None]
    local = jnp.sum(recv * mask[..., None])
    return jax.lax.psum(local, "model")[None]

rows = jnp.arange(8 * 32 * 4.0).reshape(8 * 32, 4)
dest = jnp.asarray(np.random.default_rng(0).integers(0, 8, 8 * 32))
total = jax.jit(compat.shard_map(shard_fn, mesh=mesh,
    in_specs=(P("model"), P("model")), out_specs=P("model"),
    check_vma=False))(rows, dest)
assert jnp.allclose(total[0], rows.sum()), (float(total[0]), float(rows.sum()))
print("OK")
""")


def test_moe_a2a_dispatch_under_bls_pipeline():
    """The paper's bounded-lag decoupling applied to the MoE dispatch
    all_to_all: stream microbatches, buffer the dispatched tokens k deep,
    outputs must equal the dense reference for every bound."""
    run_sub("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs.base import ModelConfig, MoEConfig
from repro.core.bls import bls_pipeline, reference_loop
from repro.models import moe as M
from repro import compat

cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                  n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=64,
                  moe=MoEConfig(n_experts=8, experts_per_token=2, d_expert=16,
                                capacity_factor=8.0),
                  dtype="float32")
mesh = compat.make_mesh((8,), ("model",))
params = M.init_moe(jax.random.PRNGKey(0), cfg, n_shards=8)
moe, e_pad, e_loc = cfg.moe, 8, 1
d = cfg.d_model

def make(bound):
    def shard_fn(router_w, gate, up, down, xs):
        # xs: (N, t_loc, d) stream of microbatches on this shard
        n_shards = 8
        t_loc = xs.shape[1]
        c_send = M.capacity(t_loc, moe.experts_per_token, n_shards,
                            moe.capacity_factor)
        c_exp = M.capacity(t_loc * n_shards, moe.experts_per_token, e_pad,
                           moe.capacity_factor)

        def stage_a(xl):
            w, idx, _ = M.route(router_w, xl, moe, e_pad)
            dest = idx // e_loc
            fe, ft, pos, valid, order = M.dispatch_indices(
                dest, n_shards, c_send)
            fw = w.reshape(-1)[order]
            fx = idx.reshape(-1)[order]
            de = jnp.where(valid, fe, n_shards)
            dp = jnp.where(valid, pos, 0)
            send = jnp.zeros((n_shards, c_send, d), xl.dtype)
            send = send.at[de, dp].set(xl[ft], mode="drop")
            eid = jnp.full((n_shards, c_send), e_loc, jnp.int32)
            eid = eid.at[de, dp].set((fx % e_loc).astype(jnp.int32),
                                     mode="drop")
            side = (de, dp, fw, valid, ft)
            return (send, eid.astype(xl.dtype)), side

        def coll(p):
            send, eid = p
            return (jax.lax.all_to_all(send, "model", 0, 0, tiled=True),
                    jax.lax.all_to_all(eid, "model", 0, 0, tiled=True))

        def stage_b(recv_p, side):
            recv, eid_f = recv_p
            de, dp, fw, valid, ft = side
            rx = recv.reshape(-1, d)
            reid = eid_f.reshape(-1, 1).astype(jnp.int32)
            fe2, ft2, pos2, valid2, _ = M.dispatch_indices(reid, e_loc, c_exp)
            buf = jnp.zeros((e_loc, c_exp, d), rx.dtype)
            buf = buf.at[jnp.where(valid2, fe2, e_loc),
                         jnp.where(valid2, pos2, 0)].set(rx[ft2], mode="drop")
            ob = M._expert_mlp({"gate": gate, "up": up, "down": down}, buf,
                               cfg.act)
            ry = ob.at[jnp.clip(fe2, 0, e_loc - 1),
                       jnp.clip(pos2, 0, c_exp - 1)].get(mode="clip")
            ry = ry * valid2[:, None].astype(ry.dtype)
            back = jnp.zeros((n_shards * c_send, d), ry.dtype).at[ft2].add(ry)
            reply = jax.lax.all_to_all(back.reshape(n_shards, c_send, d),
                                       "model", 0, 0, tiled=True)
            y = reply.reshape(-1, d)[de * c_send + dp]
            y = y * (fw * valid)[:, None].astype(y.dtype)
            return jnp.zeros((t_loc, d), y.dtype).at[ft].add(y)

        if bound is None:
            return reference_loop(stage_a, coll, stage_b, xs)
        out, _ = bls_pipeline(stage_a, coll, stage_b, xs, bound)
        return out

    return jax.jit(compat.shard_map(shard_fn, mesh=mesh,
        in_specs=(P(), P("model", None, None), P("model", None, None),
                  P("model", None, None), P(None, "model", None)),
        out_specs=P(None, "model", None), check_vma=False))

xs = jax.random.normal(jax.random.PRNGKey(1), (5, 64, 32))
f = make(None)
ref = f(params["router"], params["gate"], params["up"], params["down"], xs)
# dense oracle on the flattened stream
dense_out, _ = M.moe_ref_dense(params, cfg, xs.reshape(1, -1, 32))
assert jnp.allclose(ref.reshape(-1, 32), dense_out[0], atol=1e-4)
for k in (0, 1, 2):
    out = make(k)(params["router"], params["gate"], params["up"],
                  params["down"], xs)
    assert jnp.allclose(out, ref, atol=1e-5), k
print("OK")
""")
