"""Per-Pallas-kernel validation: shape/dtype sweeps + hypothesis against the
ref.py pure-jnp oracles (interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


class TestDotInteraction:
    @pytest.mark.parametrize("b,f,s", [(64, 27, 64), (128, 8, 16),
                                       (32, 24, 128), (256, 4, 32)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, b, f, s, dtype):
        z = jax.random.normal(jax.random.PRNGKey(0), (b, f, s), dtype)
        out = ops.dot_interaction_op(z, batch_tile=min(64, b))
        r = ref.dot_interaction_ref(z)
        tol = 1e-4 if dtype == jnp.float32 else 3e-2
        assert out.shape == (b, f * (f - 1) // 2)
        assert jnp.allclose(out.astype(jnp.float32),
                            r.astype(jnp.float32), atol=tol, rtol=tol)

    @pytest.mark.parametrize("b", [100, 37, 1])
    def test_partial_batch_tile_is_padded_internally(self, b):
        # b % batch_tile != 0 used to hard-assert; the tail tile is now
        # padded internally (mirroring the embedding-bag kernels) so odd
        # serving batch sizes run through the dense stage
        z = jax.random.normal(jax.random.PRNGKey(3), (b, 4, 8))
        out = ops.dot_interaction_op(z, batch_tile=64)
        r = ref.dot_interaction_ref(z)
        assert out.shape == r.shape
        assert jnp.allclose(out, r, atol=1e-4)


class TestEmbeddingBag:
    @pytest.mark.parametrize("r,s,b,hot", [(500, 64, 64, 4), (1000, 32, 128, 1),
                                           (64, 128, 32, 8), (2048, 16, 64, 100)])
    def test_sweep(self, r, s, b, hot):
        key = jax.random.PRNGKey(1)
        tbl = jax.random.normal(key, (r, s))
        idx = jax.random.randint(key, (b, hot), 0, r)
        mask = (jax.random.uniform(key, (b, hot)) < 0.7).astype(jnp.float32)
        out = ops.embedding_bag_op(tbl, idx, mask, batch_tile=min(32, b))
        assert jnp.allclose(out, ref.embedding_bag_ref(tbl, idx, mask),
                            atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(r=st.integers(8, 300), b=st.sampled_from([8, 16, 32]),
           hot=st.integers(1, 9), seed=st.integers(0, 2**31 - 1))
    def test_property(self, r, b, hot, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(key, 3)
        tbl = jax.random.normal(k1, (r, 16))
        idx = jax.random.randint(k2, (b, hot), 0, r)
        mask = (jax.random.uniform(k3, (b, hot)) < 0.5).astype(jnp.float32)
        out = ops.embedding_bag_op(tbl, idx, mask, batch_tile=b)
        assert jnp.allclose(out, ref.embedding_bag_ref(tbl, idx, mask),
                            atol=1e-4)

    def test_all_masked_gives_zero(self):
        tbl = jax.random.normal(jax.random.PRNGKey(0), (50, 8))
        idx = jnp.zeros((16, 3), jnp.int32)
        mask = jnp.zeros((16, 3), jnp.float32)
        out = ops.embedding_bag_op(tbl, idx, mask, batch_tile=16)
        assert jnp.allclose(out, 0.0)


class TestRwkv6Wkv:
    @pytest.mark.parametrize("b,s,h,chunk", [(2, 64, 2, 16), (1, 128, 4, 32),
                                             (3, 96, 1, 32), (2, 256, 2, 64)])
    def test_sweep(self, b, s, h, chunk):
        K = 64
        ks = jax.random.split(jax.random.PRNGKey(2), 6)
        r = jax.random.normal(ks[0], (b, s, h, K))
        k = jax.random.normal(ks[1], (b, s, h, K))
        v = jax.random.normal(ks[2], (b, s, h, K))
        logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, K)))
        u = jax.random.normal(ks[4], (h, K)) * 0.5
        s0 = jax.random.normal(ks[5], (b, h, K, K)) * 0.1
        out, sout = ops.rwkv6_wkv_op(r, k, v, logw, u, s0, chunk=chunk)
        ro, rs = ref.rwkv6_wkv_ref(r, k, v, logw, u, s0)
        assert jnp.allclose(out, ro, atol=5e-4), (b, s, h, chunk)
        assert jnp.allclose(sout, rs, atol=5e-4)

    def test_extreme_decay_no_overflow(self):
        """Very fast decay (log w << 0) must stay exact — the safety the
        in-kernel pre-mask gives (upper-triangle exponents are +inf)."""
        b, s, h, K = 1, 64, 1, 64
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        r = jax.random.normal(ks[0], (b, s, h, K))
        k = jax.random.normal(ks[1], (b, s, h, K))
        v = jax.random.normal(ks[2], (b, s, h, K))
        logw = jnp.full((b, s, h, K), -50.0)  # state dies each step
        u = jnp.ones((h, K))
        s0 = jnp.zeros((b, h, K, K))
        out, _ = ops.rwkv6_wkv_op(r, k, v, logw, u, s0, chunk=16)
        ro, _ = ref.rwkv6_wkv_ref(r, k, v, logw, u, s0)
        assert bool(jnp.all(jnp.isfinite(out)))
        assert jnp.allclose(out, ro, atol=1e-4)


def test_kernels_match_model_usage():
    """kernels/ops must agree with the model-level chunked implementation."""
    from repro.models.rwkv6 import wkv_chunked

    b, s, h, K = 2, 128, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    r = jax.random.normal(ks[0], (b, s, h, K))
    k = jax.random.normal(ks[1], (b, s, h, K))
    v = jax.random.normal(ks[2], (b, s, h, K))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, K)))
    u = jax.random.normal(ks[4], (h, K)) * 0.5
    s0 = jnp.zeros((b, h, K, K))
    o1, s1 = ops.rwkv6_wkv_op(r, k, v, logw, u, s0, chunk=32)
    o2, s2 = wkv_chunked(r, k, v, logw, u, s0, chunk=32)
    assert jnp.allclose(o1, o2, atol=5e-4)
    assert jnp.allclose(s1, s2, atol=5e-4)
