"""The fused sparse hot path (DESIGN.md): stacked-table Pallas embedding
bags, wire codecs for the butterfly exchange, and the cache-aware
distributed forward.  Parity oracle everywhere: ``forward_local`` /
pure-jnp references."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DLRMConfig
from repro.core import alltoallv as A2A
from repro.data import synthetic as S
from repro.kernels import ops, ref
from repro.models import dlrm as D
from repro.serving import hot_cache as HC

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# wire codecs
# ---------------------------------------------------------------------------


class TestWireCodecs:
    def _x(self, shape=(16, 6, 8), seed=0, scale=3.0):
        return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale

    def test_float32_is_identity(self):
        x = self._x()
        p = A2A.encode_wire(x, "float32")
        assert p["q"] is x
        assert jnp.array_equal(A2A.decode_wire(p), x)

    def test_bfloat16_roundtrip_error_bound(self):
        x = self._x()
        y = A2A.decode_wire(A2A.encode_wire(x, "bfloat16"))
        # bf16 has 8 significand bits -> relative error < 2^-8
        assert float(jnp.max(jnp.abs(y - x))) < float(jnp.max(jnp.abs(x))) / 128

    def test_int8_per_row_scale_error_bound(self):
        # rows with wildly different magnitudes: per-row scales keep the
        # small rows accurate (a per-tensor scale would zero them out)
        big = self._x((4, 2, 8), seed=1, scale=100.0)
        small = self._x((4, 2, 8), seed=2, scale=0.01)
        x = jnp.concatenate([big, small], axis=0)
        y = A2A.decode_wire(A2A.encode_wire(x, "int8"))
        row_max = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        assert bool(jnp.all(jnp.abs(y - x) <= row_max / 127.0 + 1e-6))

    def test_int8_scale_is_bf16_and_never_saturates(self):
        # the per-row scale ships as bf16 (2 bytes, not 4); the up-nudged
        # down-cast must keep quantization against the stored scale inside
        # [-127, 127] and the roundtrip inside the f32-scale error bound
        x = self._x((64, 4, 16), seed=3, scale=10.0)
        p = A2A.encode_wire(x, "int8")
        assert p["scale"].dtype == jnp.bfloat16
        raw = jnp.round(x.astype(jnp.float32) /
                        p["scale"].astype(jnp.float32))
        assert float(jnp.max(jnp.abs(raw))) <= 127.0
        y = A2A.decode_wire(p)
        row_max = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        assert bool(jnp.all(jnp.abs(y - x) <= row_max / 127.0 + 1e-6))

    def test_zero_rows_quantize_exactly(self):
        x = jnp.zeros((8, 3, 16))
        for wire in ("float32", "bfloat16", "int8"):
            assert float(jnp.max(jnp.abs(
                A2A.decode_wire(A2A.encode_wire(x, wire))))) == 0.0

    def test_unknown_wire_raises(self):
        with pytest.raises(ValueError):
            A2A.encode_wire(jnp.ones((2, 2)), "float8")

    def test_wire_stats_accounting(self):
        mask = jnp.asarray([[[1, 1], [0, 0], [1, 0]],
                            [[0, 0], [0, 0], [0, 1]]], jnp.float32)
        st = A2A.wire_stats(mask, embed_dim=4, wire_dtype="bfloat16")
        assert st.total_rows == 6
        assert st.live_rows == 3
        assert st.ref_bytes == 6 * 4 * 4
        assert st.dense_bytes == 6 * 4 * 2
        assert st.live_bytes == 3 * 4 * 2
        assert st.reduction_vs_ref == pytest.approx(1 - 24 / 96)
        st8 = A2A.wire_stats(mask, embed_dim=4, wire_dtype="int8")
        assert st8.live_bytes == 3 * (4 * 1 + 2)  # + per-row bf16 scale


# ---------------------------------------------------------------------------
# stacked-table kernel
# ---------------------------------------------------------------------------


class TestStackedEmbeddingBag:
    @pytest.mark.parametrize("t,r,s,b,hot", [(5, 40, 16, 32, 4),
                                             (3, 100, 8, 64, 1),
                                             (8, 30, 32, 16, 7)])
    def test_sweep_vs_ref(self, t, r, s, b, hot):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        tbl = jax.random.normal(ks[0], (t, r, s))
        idx = jax.random.randint(ks[1], (b, t, hot), 0, r)
        mask = (jax.random.uniform(ks[2], (b, t, hot)) < 0.6) \
            .astype(jnp.float32)
        out = ops.embedding_bag_stacked_op(tbl, idx, mask, batch_tile=16)
        want = ref.embedding_bag_stacked_ref(tbl, idx, mask)
        assert out.shape == (b, t, s)
        assert jnp.allclose(out, want, atol=1e-4)

    def test_matches_single_table_kernel(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        tbl = jax.random.normal(ks[0], (4, 50, 8))
        idx = jax.random.randint(ks[1], (16, 4, 3), 0, 50)
        mask = jnp.ones((16, 4, 3), jnp.float32)
        stacked = ops.embedding_bag_stacked_op(tbl, idx, mask, batch_tile=16)
        for ti in range(4):
            single = ops.embedding_bag_op(tbl[ti], idx[:, ti], mask[:, ti],
                                          batch_tile=16)
            assert jnp.allclose(stacked[:, ti], single, atol=1e-5), ti

    def test_apply_emb_backend_dispatch(self):
        cfg = DLRMConfig(name="t", table_sizes=(60, 40, 80), embed_dim=8,
                         max_hot=4)
        tbl = jax.random.normal(jax.random.PRNGKey(2), (3, 80, 8))
        b = S.make_batch(cfg, 24, mode="hetero", seed=3)
        idx, mask = jnp.asarray(b.idx), jnp.asarray(b.mask)
        r = D.apply_emb(tbl, idx, mask, "ref")
        k = D.apply_emb(tbl, idx, mask, "interpret")
        assert jnp.allclose(r, k, atol=1e-4)
        with pytest.raises(ValueError):
            D.apply_emb(tbl, idx, mask, "cuda")

    def test_forward_local_backends_agree(self):
        cfg = DLRMConfig(name="t", table_sizes=(60, 40, 80), embed_dim=8,
                         bottom_mlp=(16, 8), top_mlp=(16, 1), max_hot=4)
        params = D.init_dlrm(jax.random.PRNGKey(0), cfg, n_shards=1)
        b = S.make_batch(cfg, 16, mode="hetero", seed=1)
        dense, idx, mask = map(jnp.asarray, (b.dense, b.idx, b.mask))
        out_ref = D.forward_local(params, cfg, dense, idx, mask)
        cfg_k = cfg.replace(sparse_backend="interpret")
        out_k = D.forward_local(params, cfg_k, dense, idx, mask)
        assert jnp.allclose(out_ref, out_k, atol=1e-4)


# ---------------------------------------------------------------------------
# cache split helpers
# ---------------------------------------------------------------------------


class TestCacheSplit:
    def _setup(self, cache_rows, mode="powerlaw"):
        cfg = DLRMConfig(name="t", table_sizes=(500, 300, 400), embed_dim=8,
                         max_hot=4)
        tables = jax.random.normal(jax.random.PRNGKey(0), (3, 500, 8))
        b = S.make_batch(cfg, 48, mode=mode, seed=1)
        idx, mask = jnp.asarray(b.idx), jnp.asarray(b.mask)
        cache = HC.build_from_batch(tables, b.idx, b.mask, cache_rows)
        return tables, cache, idx, mask

    def test_split_helpers_match_lookup(self):
        tables, cache, idx, mask = self._setup(16)
        hits, miss = HC.lookup(cache, idx, mask)
        assert jnp.array_equal(
            miss, HC.miss_mask_of(cache.slot_of, idx, mask))
        assert jnp.allclose(
            hits, HC.pooled_hits_of(cache.hot_rows, cache.slot_of, idx,
                                    mask))

    def test_cache_rows_zero_degenerate(self):
        tables, cache, idx, mask = self._setup(0)
        assert cache.cache_rows == 0
        hits, miss = HC.lookup(cache, idx, mask)
        assert float(jnp.max(jnp.abs(hits))) == 0.0
        assert jnp.array_equal(miss, mask)
        assert HC.hit_rate(cache, idx, mask) == 0.0

    def test_hits_plus_misses_cover_full_bag(self):
        tables, cache, idx, mask = self._setup(16)
        hits, miss = HC.lookup(cache, idx, mask)
        full = D.apply_emb(tables, idx, mask)
        misses = D.apply_emb(tables, idx, miss)
        assert jnp.allclose(hits + misses, full, atol=1e-5)


# ---------------------------------------------------------------------------
# fused distributed parity (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------


def run_sub(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_fused_distributed_matches_local():
    """Fused (cache + quantized wire) logits match forward_local within the
    wire dtype's tolerance across bounds k in {0, 2} and hit rates
    {0, ~0.5, ~1.0} (cache_rows {0, 40, 100}); float32 wire with no cache
    is the bit-identical reference path."""
    run_sub("""
import jax, jax.numpy as jnp
from repro import compat
from repro.configs.base import DLRMConfig
from repro.models import dlrm as D
from repro.data import synthetic as S
from repro.serving import hot_cache as HC
from repro.sharding import partition

cfg = DLRMConfig(name="t", table_sizes=(100, 50, 80, 60, 90, 40),
                 embed_dim=16, bottom_mlp=(32, 16), top_mlp=(32, 1),
                 max_hot=4)
mesh = compat.make_mesh((2, 4), ("data", "model"))
params = D.init_dlrm(jax.random.PRNGKey(0), cfg, n_shards=4)
b = S.make_batch(cfg, 64, mode="hetero", t_pad=D.padded_tables(cfg, 4),
                 seed=1)
dense, idx, mask = map(jnp.asarray, (b.dense, b.idx, b.mask))
ref = D.forward_local(params, cfg, dense, idx, mask)
TOL = {"float32": 1e-4, "bfloat16": 5e-2, "int8": 1e-1}
caches = {rows: HC.build_from_batch(params["tables"], b.idx, b.mask, rows)
          for rows in (0, 40, 100)}
hr = {rows: HC.hit_rate(c, idx, mask) for rows, c in caches.items()}
assert hr[0] == 0.0 and 0.3 < hr[40] < 0.95 and hr[100] == 1.0, hr
with partition.axis_rules(mesh):
    for bound, mb in [(0, 1), (2, 4)]:
        for wire, tol in TOL.items():
            for rows, cache in caches.items():
                out = jax.jit(lambda p, d, i, m, bound=bound, mb=mb,
                              w=wire, c=cache:
                              D.forward_distributed(p, cfg, d, i, m,
                                                    bound=bound,
                                                    microbatches=mb,
                                                    cache=c, wire_dtype=w)
                              )(params, dense, idx, mask)
                err = float(jnp.max(jnp.abs(out - ref)))
                assert err < tol, (bound, wire, rows, err)
                # full-hit cache: nothing on the wire -> exact parity with
                # the f32 path even under lossy codecs
                if rows == 100:
                    assert err < 1e-4, (bound, wire, rows, err)
print("OK")
""")


def test_fused_wire_payload_shrinks():
    """Acceptance: under power-law skew + ragged bags, the cache+bf16
    exchange moves >= 40% fewer payload bytes than the f32 reference."""
    cfg = DLRMConfig(name="t", table_sizes=(500, 300, 400, 200), embed_dim=16,
                     max_hot=4)
    b = S.make_batch(cfg, 128, mode="powerlaw_hetero", seed=0)
    tables = jax.random.normal(jax.random.PRNGKey(0), (4, 500, 16))
    cache = HC.build_from_batch(tables, b.idx, b.mask, 32)
    idx, mask = jnp.asarray(b.idx), jnp.asarray(b.mask)
    _, miss_mask = HC.lookup(cache, idx, mask)
    st = A2A.wire_stats(miss_mask, cfg.embed_dim, "bfloat16")
    assert st.reduction_vs_ref >= 0.40, st
    # bf16 alone halves the dense exchange even with no cache
    st_dense = A2A.wire_stats(mask, cfg.embed_dim, "bfloat16")
    assert st_dense.reduction_vs_ref == pytest.approx(0.5)
