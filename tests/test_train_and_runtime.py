"""Training loop, optimizer, grad accumulation, checkpointing, data pipeline."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import api
from repro.runtime import checkpoint as C
from repro.train import optimizer as opt_mod
from repro.train import steps as steps_mod


def _tiny_cfg():
    return ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                       dtype="float32", remat="none")


def test_loss_decreases_over_steps():
    cfg = _tiny_cfg()
    key = jax.random.PRNGKey(0)
    params = api.init(key, cfg, 1)
    opt_state = opt_mod.adamw_init(params)
    step = jax.jit(steps_mod.make_train_step(cfg, peak_lr=1e-2))
    toks = jax.random.randint(key, (4, 32), 0, 128)
    batch = {"tokens": toks, "labels": toks}
    losses = []
    for _ in range(30):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_grad_accumulation_equivalent():
    cfg = _tiny_cfg()
    key = jax.random.PRNGKey(1)
    params = api.init(key, cfg, 1)
    batch = {"tokens": jax.random.randint(key, (8, 16), 0, 128),
             "labels": jax.random.randint(key, (8, 16), 0, 128)}
    s1 = steps_mod.make_train_step(cfg, accum_steps=1)
    s4 = steps_mod.make_train_step(cfg, accum_steps=4)
    p1, _, m1 = s1(params, opt_mod.adamw_init(params), batch)
    p4, _, m4 = s4(params, opt_mod.adamw_init(params), batch)
    assert jnp.allclose(m1["loss"], m4["loss"], atol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        assert jnp.allclose(a, b, atol=1e-5)


def test_cosine_schedule():
    lr0 = opt_mod.cosine_schedule(jnp.int32(0), peak_lr=1e-3, warmup=10,
                                  total=100)
    lr_peak = opt_mod.cosine_schedule(jnp.int32(10), peak_lr=1e-3, warmup=10,
                                      total=100)
    lr_end = opt_mod.cosine_schedule(jnp.int32(100), peak_lr=1e-3, warmup=10,
                                     total=100)
    assert float(lr0) == pytest.approx(1e-4)  # step 0 already steps
    assert float(lr_peak) == pytest.approx(1e-3, rel=0.11)
    assert float(lr_end) == pytest.approx(1e-4, rel=1e-3)  # floor 0.1


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = opt_mod.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0)


class TestCheckpoint:
    def test_save_restore_roundtrip(self):
        tree = {"layer": {"w": jnp.arange(12.0).reshape(3, 4)},
                "step_count": jnp.int32(7)}
        with tempfile.TemporaryDirectory() as d:
            C.save(d, 5, tree)
            restored, step = C.restore(d, tree)
            assert step == 5
            assert jnp.allclose(restored["layer"]["w"], tree["layer"]["w"])
            assert int(restored["step_count"]) == 7

    def test_latest_pointer_and_gc(self):
        tree = {"w": jnp.ones((2,))}
        with tempfile.TemporaryDirectory() as d:
            for s in (1, 2, 3, 4, 5):
                C.save(d, s, tree, keep=2)
            assert C.latest_step(d) == 5
            kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
            assert kept == ["step_00000004", "step_00000005"]

    def test_atomicity_partial_write_ignored(self):
        tree = {"w": jnp.ones((2,))}
        with tempfile.TemporaryDirectory() as d:
            C.save(d, 1, tree)
            # simulate a torn write of step 2
            os.makedirs(os.path.join(d, "step_00000002.tmp"))
            restored, step = C.restore(d, tree)
            assert step == 1

    def test_async_checkpointer(self):
        tree = {"w": jnp.arange(8.0)}
        with tempfile.TemporaryDirectory() as d:
            ck = C.AsyncCheckpointer(d)
            ck.save(1, tree)
            ck.save(2, jax.tree.map(lambda x: x * 2, tree))
            ck.wait()
            restored, step = C.restore(d, tree)
            assert step == 2
            assert jnp.allclose(restored["w"], tree["w"] * 2)

    def test_missing_leaf_raises(self):
        with tempfile.TemporaryDirectory() as d:
            C.save(d, 1, {"w": jnp.ones((2,))})
            with pytest.raises(KeyError):
                C.restore(d, {"w": jnp.ones((2,)), "extra": jnp.ones((1,))})


class TestDataPipeline:
    def test_prefetcher_order_and_exhaustion(self):
        from repro.data.pipeline import Prefetcher
        out = list(Prefetcher(iter(range(10)), depth=3))
        assert out == list(range(10))

    def test_prefetcher_propagates_errors(self):
        from repro.data.pipeline import Prefetcher

        def gen():
            yield 1
            raise RuntimeError("boom")

        it = Prefetcher(gen(), depth=2)
        assert next(it) == 1
        with pytest.raises(RuntimeError):
            for _ in it:
                pass

    def test_synthetic_modes(self):
        from repro.configs.base import DLRMConfig
        from repro.data import synthetic as S
        cfg = DLRMConfig(name="t", table_sizes=(50, 100, 20), embed_dim=8,
                         max_hot=5)
        uni = S.make_batch(cfg, 64, mode="uniform", seed=0)
        het = S.make_batch(cfg, 64, mode="hetero", seed=0)
        pl = S.make_batch(cfg, 64, mode="powerlaw", seed=0)
        assert uni.idx.shape == (64, 3, 1)
        assert het.idx.shape == (64, 3, 5)
        stats = S.hot_counts_stats(het)
        assert 1.0 <= stats["mean_hot"] <= 5.0
        assert stats["message_cv"] > 0.05  # Setting 1: heterogeneous sizes
        # indices in range
        for b in (uni, het, pl):
            for t, n in enumerate(cfg.table_sizes):
                assert b.idx[:, t].max() < n
        # determinism per (seed, step)
        again = S.make_batch(cfg, 64, mode="hetero", seed=0)
        assert np.array_equal(het.idx, again.idx)
