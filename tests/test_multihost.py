"""Multi-host utilities (single-host degenerate paths + slicing logic)."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_host_mesh
from repro.launch.multihost import (bringup, form_global_array,
                                    host_batch_slice)


def test_bringup_single_host():
    info = bringup()
    assert info["process_index"] == 0
    assert info["process_count"] == 1


def test_host_batch_slice():
    assert host_batch_slice(64) == (0, 64)
    # logic check for the multi-host formula (pure arithmetic)
    per = 256 // 8
    assert [(i * per, (i + 1) * per) for i in range(8)][3] == (96, 128)


def test_form_global_array_roundtrip():
    mesh = make_host_mesh()
    local = np.arange(16.0).reshape(8, 2)
    arr = form_global_array(local, mesh, P("data", None))
    assert arr.shape == (8, 2)
    assert np.allclose(np.asarray(arr), local)
