"""Multi-device equivalence tests.  jax locks the device count at first init,
so these run in subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_dlrm_distributed_matches_local_all_bounds():
    run_sub("""
import jax, jax.numpy as jnp
from repro.configs.base import DLRMConfig
from repro.models import dlrm as D
from repro.data import synthetic as S
from repro.sharding import partition

cfg = DLRMConfig(name="t", table_sizes=(100, 50, 80, 60, 90, 40),
                 embed_dim=16, bottom_mlp=(32, 16), top_mlp=(32, 1),
                 max_hot=4)
from repro import compat
mesh = compat.make_mesh((2, 4), ("data", "model"))
params = D.init_dlrm(jax.random.PRNGKey(0), cfg, n_shards=4)
b = S.make_batch(cfg, 64, mode="hetero", t_pad=D.padded_tables(cfg, 4), seed=1)
dense, idx, mask = map(jnp.asarray, (b.dense, b.idx, b.mask))
ref = D.forward_local(params, cfg, dense, idx, mask)
with partition.axis_rules(mesh):
    for bound, mb in [(0, 1), (0, 4), (1, 4), (2, 4), (3, 8)]:
        out = jax.jit(lambda p, d, i, m, bound=bound, mb=mb:
                      D.forward_distributed(p, cfg, d, i, m, bound=bound,
                                            microbatches=mb))(params, dense, idx, mask)
        assert jnp.allclose(out, ref, atol=1e-4), (bound, mb)
print("OK")
""")


def test_bls_pipeline_with_real_all_to_all():
    run_sub("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.bls import bls_pipeline, reference_loop
from repro import compat
mesh = compat.make_mesh((2, 4), ("data", "model"))
def run(bound):
    def shard_fn(x):
        a = lambda xj: (xj * 1.0, xj.sum(axis=(1, 2)))
        c = lambda p: jax.lax.all_to_all(p, "model", 0, 1, tiled=True)
        b = lambda recv, side: recv.sum(axis=(1, 2)) + 0.1 * side[:recv.shape[0]]
        if bound is None:
            return reference_loop(a, c, b, x)
        out, _ = bls_pipeline(a, c, b, x, bound)
        return out
    return jax.jit(compat.shard_map(shard_fn, mesh=mesh,
        in_specs=P(None, "data", "model", None),
        out_specs=P(None, ("data", "model")), check_vma=False))
x = jax.random.normal(jax.random.PRNGKey(0), (5, 8, 4, 6))
ref = run(None)(x)
for k in [0, 1, 2, 3]:
    assert jnp.allclose(run(k)(x), ref, atol=1e-5), k
print("OK")
""")


def test_moe_a2a_matches_gather_and_ref():
    run_sub("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.configs.base import ModelConfig, MoEConfig
from repro.models import moe as M
from repro.sharding import partition

cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                  n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=64,
                  moe=MoEConfig(n_experts=8, experts_per_token=2, d_expert=16,
                                capacity_factor=8.0),
                  dtype="float32")
from repro import compat
mesh = compat.make_mesh((2, 4), ("data", "model"))
params = M.init_moe(jax.random.PRNGKey(0), cfg, n_shards=4)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
ref, _ = M.moe_ref_dense(params, cfg, x)
with partition.axis_rules(mesh):
    g, _ = jax.jit(lambda p, x: M.moe_gather(p, cfg, x))(params, x)
    a, _ = jax.jit(lambda p, x: M.moe_a2a(p, cfg, x))(params, x)
print("gather diff", float(jnp.max(jnp.abs(g - ref))))
print("a2a diff", float(jnp.max(jnp.abs(a - ref))))
assert jnp.allclose(g, ref, atol=1e-4)
assert jnp.allclose(a, ref, atol=1e-4)
print("OK")
""")


def test_checkpoint_cross_mesh_restore():
    run_sub("""
import jax, jax.numpy as jnp, tempfile
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.runtime import checkpoint as C

tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones((8,))}
with tempfile.TemporaryDirectory() as d:
    C.save(d, 3, tree)
    # restore onto a 2x4 mesh with model sharding (elastic re-mesh)
    from repro import compat
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    shardings = {"w": NamedSharding(mesh, P("data", "model")),
                 "b": NamedSharding(mesh, P("model"))}
    restored, step = C.restore(d, tree, shardings=shardings)
    assert step == 3
    assert jnp.allclose(restored["w"], tree["w"])
    assert restored["w"].sharding.spec == P("data", "model")
print("OK")
""")
