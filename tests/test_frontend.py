"""Overload-robust serving frontend (DESIGN.md §9).

Two layers of coverage:

  * deterministic policy tests — a virtual clock + a fake engine make
    every admission / shed / backpressure / ladder decision exactly
    reproducible (no wall-clock flakes): the conservation invariant
    (admitted == served + degraded_served + shed, requests never lost or
    double-counted), deadline-monotone shedding, growing-and-honored
    backpressure hints, ladder escalation/de-escalation, and pipelined
    FIFO result attribution;
  * real-engine tests — bit-parity of batched-vs-individually-flushed
    CTRs for admitted requests, lookahead plan staging hitting the PR 4
    hook, drain idempotency, and the JSON stats surface.
"""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data import synthetic as S
from repro.runtime.faults import FaultInjector, FaultPlan
from repro.serving.engine import ServeStats
from repro.serving.frontend import (ADMITTED, RETRY_AFTER, FrontendStats,
                                    LatencyHistogram, ServingFrontend)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class VClock:
    """Virtual monotonic clock: time moves only when a test says so."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


class FakeEngine:
    """Minimal DLRMEngine stand-in honoring the frontend's contract:
    submit() auto-flushes at batch_size, flush() returns the pending
    batch's CTRs (or, with ``deferred=True``, the PREVIOUS batch's — the
    plan-pipeline calling convention).  Each request's "CTR" is its
    submission ordinal so attribution is checkable bit-for-bit; flushing
    advances the shared virtual clock by ``service_s``."""

    def __init__(self, clock: VClock, *, batch_size=8, service_s=0.005,
                 deferred=False):
        self.clock = clock
        self.batch_size = batch_size
        self.service_s = service_s
        self.deferred = deferred
        self.plan_pipeline = deferred
        self.cache = None
        self.stats = ServeStats()
        self.degraded_members: tuple = ()
        self.degrade_calls: list = []
        self._pending: list = []
        self._inflight = None
        self._n = 0
        self.staged: list = []

    def submit(self, dense, idx, mask):
        self._pending.append(self._n)
        self._n += 1
        if len(self._pending) >= self.batch_size:
            return self.flush()
        return None

    def flush(self):
        if not self._pending:
            if self._inflight is not None:
                out, self._inflight = self._inflight, None
                return out
            return None
        out = np.asarray(self._pending, np.float64)
        self._pending.clear()
        self.clock.advance(self.service_s)
        self.stats.batches += 1
        self.stats.requests += len(out)
        if self.deferred:
            prev, self._inflight = self._inflight, out
            return prev
        return out

    def drain(self):
        outs = [o for o in (self.flush(), self.flush()) if o is not None]
        return np.concatenate(outs) if outs else None

    def degrade(self, members):
        self.degraded_members = tuple(members)
        self.degrade_calls.append(tuple(members))

    def stage_plan(self, idx_rows):
        self.staged.append(len(list(idx_rows)))
        return True


def drive(fe, clock, requests, *, idle_dt=0.001):
    """Open-loop driver on the virtual clock: submit each request at its
    arrival time, pump in between, drain at the end.  Returns (completed,
    submit_results)."""
    completed, results = [], []
    for r in requests:
        if r.t_arrive > clock.t:
            clock.t = r.t_arrive
        results.append(fe.try_submit(r.dense, r.idx, r.mask))
        got = fe.pump()
        completed += got
        assert fe.stats.accounted, "invariant broke mid-stream"
        if not got:
            clock.advance(idle_dt)
    completed += fe.drain()
    return completed, results


def _reqs(n, *, rate=2000.0, burstiness=0.5, seed=0):
    from repro.configs.base import DLRMConfig
    cfg = DLRMConfig("t", table_sizes=(40, 60, 30), embed_dim=4,
                     n_dense_features=2, bottom_mlp=(4,), top_mlp=(4, 1))
    return S.request_stream(cfg, n, rate_rps=rate, burstiness=burstiness,
                            seed=seed)


# ---------------------------------------------------------------------------
# deterministic policy tests (virtual clock + fake engine)
# ---------------------------------------------------------------------------


class TestAccounting:
    def test_invariant_under_seeded_bursty_traffic(self):
        clock = VClock()
        eng = FakeEngine(clock, batch_size=8, service_s=0.004)
        fe = ServingFrontend(eng, slo_s=0.05, max_queue=24,
                             admission="slo", init_flush_s=0.004,
                             clock=clock, seed=1)
        completed, results = drive(fe, clock, _reqs(300, seed=11))
        st = fe.stats
        assert st.offered == 300
        assert st.admitted + st.rejected == st.offered
        assert st.admitted == sum(r.admitted for r in results)
        # zero lost-or-unaccounted: exact conservation after drain
        assert st.queued == 0 and st.inflight == 0
        assert st.admitted == st.served + st.degraded_served + st.shed
        assert len(completed) == st.completed
        # every completed request is unique (never double-served)
        rids = [c.request_id for c in completed]
        assert len(rids) == len(set(rids))
        assert st.accounted

    def test_pipelined_attribution_is_fifo_exact(self):
        clock = VClock()
        eng = FakeEngine(clock, batch_size=4, service_s=0.002,
                         deferred=True)
        fe = ServingFrontend(eng, slo_s=1.0, admission="none", shed=False,
                             init_flush_s=0.002, clock=clock,
                             lookahead=False)
        completed, _ = drive(fe, clock, _reqs(37, burstiness=0.0, seed=2))
        assert fe.stats.admitted == 37 == fe.stats.completed
        # the fake CTR is the submission ordinal == frontend request id:
        # deferred (one-flush-late) results must still map 1:1
        for c in completed:
            assert c.ctr == float(c.request_id)

    def test_histograms_and_to_dict_are_plain_json(self):
        clock = VClock()
        eng = FakeEngine(clock)
        fe = ServingFrontend(eng, slo_s=0.1, clock=clock,
                             init_flush_s=0.005)
        drive(fe, clock, _reqs(50, seed=3))
        d = fe.stats.to_dict()
        js = json.loads(json.dumps(d))          # round-trips as plain JSON
        assert js["admitted"] == fe.stats.admitted
        assert js["e2e"]["count"] == fe.stats.completed
        assert js["queue_delay"]["p99_ms"] >= 0
        assert js["accounted"] is True
        # engine-level ledger rides the SAME object (shared stats)
        assert js["batches"] == eng.stats.batches
        assert eng.stats is fe.stats


class TestShedding:
    def test_shed_decision_is_deadline_monotone(self):
        clock = VClock()
        eng = FakeEngine(clock, batch_size=32, service_s=0.010)
        fe = ServingFrontend(eng, slo_s=10.0, admission="queue",
                             init_flush_s=0.010, clock=clock, shed=True)
        reqs = _reqs(20, burstiness=0.0, seed=4)
        deadlines = np.linspace(0.001, 0.040, 20)
        for r, dl in zip(reqs, deadlines):
            assert fe.try_submit(r.dense, r.idx, r.mask,
                                 deadline_s=float(dl)).admitted
        clock.advance(0.015)    # some deadlines are now unservable
        cutoff = fe.shed_cutoff(clock())
        # absolute deadlines (all admitted at t=0): shed iff dl < cutoff
        expect_shed = int(sum(dl < cutoff for dl in deadlines))
        completed = fe.pump() + fe.drain()
        assert fe.stats.shed == expect_shed > 0
        assert fe.stats.completed == 20 - expect_shed
        # monotonicity: every shed deadline precedes every served deadline
        served_dl = [c.deadline for c in completed]
        assert min(served_dl) >= cutoff - 1e-12
        assert 0 < expect_shed < 20        # the cutoff actually split them

    def test_no_shed_when_disabled(self):
        clock = VClock()
        eng = FakeEngine(clock, batch_size=8, service_s=0.050)
        fe = ServingFrontend(eng, slo_s=0.001, admission="none",
                             shed=False, init_flush_s=0.050, clock=clock)
        completed, _ = drive(fe, clock, _reqs(30, seed=5))
        assert fe.stats.shed == 0
        assert fe.stats.completed == 30       # everything served, late
        assert fe.stats.served_late > 0


class TestBackpressure:
    def test_retry_hints_grow_and_are_honored(self):
        clock = VClock()
        eng = FakeEngine(clock, batch_size=4, service_s=0.002)
        fe = ServingFrontend(eng, slo_s=1.0, max_queue=4,
                             admission="queue", init_flush_s=0.002,
                             clock=clock, retry_base_s=0.004, seed=7)
        r = _reqs(1, seed=6)[0]
        for _ in range(4):
            assert fe.try_submit(r.dense, r.idx, r.mask).admitted
        # queue full: rejections with exponentially growing jittered hints
        hints = [fe.try_submit(r.dense, r.idx, r.mask) for _ in range(4)]
        assert all(h.status == RETRY_AFTER for h in hints)
        assert all(h.retry_after_s > 0 for h in hints)
        # jitter is < 1.5x, so two doublings always dominate it
        assert hints[2].retry_after_s > hints[0].retry_after_s
        assert hints[3].retry_after_s > hints[1].retry_after_s
        assert fe.stats.rejected == 4
        # honor the hint: wait it out, let the queue drain, resubmit
        clock.advance(max(h.retry_after_s for h in hints))
        fe.pump()
        got = fe.try_submit(r.dense, r.idx, r.mask)
        assert got.admitted
        assert fe.stats.retried == 1          # backpressure round-trip
        # streak reset: the next rejection starts small again
        for _ in range(3):
            fe.try_submit(r.dense, r.idx, r.mask)
        h2 = fe.try_submit(r.dense, r.idx, r.mask)
        assert h2.status == RETRY_AFTER
        assert h2.retry_after_s <= fe.retry_base_s * 1.5 + 1e-12

    def test_slo_admission_rejects_predicted_breach(self):
        clock = VClock()
        eng = FakeEngine(clock, batch_size=4, service_s=0.020)
        fe = ServingFrontend(eng, slo_s=0.025, max_queue=1000,
                             admission="slo", init_flush_s=0.020,
                             clock=clock)
        r = _reqs(1, seed=8)[0]
        oks = [fe.try_submit(r.dense, r.idx, r.mask) for _ in range(12)]
        # one batch ahead fits the SLO; three batches ahead cannot
        assert oks[0].admitted
        assert any(not o.admitted for o in oks)
        first_reject = next(i for i, o in enumerate(oks) if not o.admitted)
        # the predicate is queue-depth monotone: everything after the
        # first rejection point with the same deadline is also rejected
        assert all(o.admitted for o in oks[:first_reject])


class TestLadder:
    def _overload(self, fe, clock, eng, n=60):
        r = _reqs(1, seed=9)[0]
        for _ in range(n):
            fe.try_submit(r.dense, r.idx, r.mask)
            fe.pump()
            clock.advance(0.0005)

    def test_escalates_under_sustained_overload_and_recovers(self):
        clock = VClock()
        eng = FakeEngine(clock, batch_size=4, service_s=0.030)
        fe = ServingFrontend(eng, slo_s=0.010, admission="none",
                             shed=False, init_flush_s=0.030, clock=clock,
                             degrade_members=(1,), escalate_after=2,
                             deescalate_after=3, window=16)
        self._overload(fe, clock, eng)
        assert fe.stats.level >= 1
        assert fe.stats.escalations >= 1
        # DEGRADED engaged the engine's approximate serve
        assert (1,) in eng.degrade_calls
        assert fe.stats.degraded_served > 0
        # recovery: fast service, idle pumps -> de-escalate to FULL and
        # restore exact serving
        eng.service_s = 0.0001
        fe._recent_e2e.clear()
        for _ in range(40):
            fe.pump()
            clock.advance(0.001)
        fe.drain()
        assert fe.stats.level == 0
        assert fe.stats.deescalations >= 1
        assert eng.degraded_members == ()

    def test_degraded_served_counted_separately(self):
        clock = VClock()
        eng = FakeEngine(clock, batch_size=4, service_s=0.030)
        fe = ServingFrontend(eng, slo_s=0.010, admission="none",
                             shed=False, init_flush_s=0.030, clock=clock,
                             escalate_after=1, window=8)
        self._overload(fe, clock, eng, n=40)
        fe.drain()
        st = fe.stats
        assert st.degraded_served > 0 and st.served > 0
        assert st.served + st.degraded_served + st.shed == st.admitted


class TestShaping:
    def test_partial_batch_waits_then_dispatches_on_budget(self):
        clock = VClock()
        eng = FakeEngine(clock, batch_size=8, service_s=0.010)
        fe = ServingFrontend(eng, slo_s=0.100, admission="queue",
                             init_flush_s=0.010, clock=clock,
                             linger_s=10.0)       # linger can't be the cause
        r = _reqs(1, seed=10)[0]
        fe.try_submit(r.dense, r.idx, r.mask)
        # plenty of slack: the frontend lingers for batch-mates
        assert fe.pump() == []
        assert fe.stats.queued == 1
        clock.t = 0.050                           # still affordable
        assert fe.pump() == []
        # budget exhausted: deadline minus EWMA*headroom reached -> go
        clock.t = 0.100 - 0.010 * fe.dispatch_headroom + 1e-6
        got = fe.pump()
        assert len(got) == 1
        assert fe.stats.queued == 0

    def test_linger_bounds_the_wait(self):
        clock = VClock()
        eng = FakeEngine(clock, batch_size=8, service_s=0.001)
        fe = ServingFrontend(eng, slo_s=10.0, admission="queue",
                             init_flush_s=0.001, clock=clock,
                             linger_s=0.020)
        r = _reqs(1, seed=15)[0]
        fe.try_submit(r.dense, r.idx, r.mask)
        assert fe.pump() == []                    # deadline is far away
        clock.advance(0.021)                      # ...but linger expired
        assert len(fe.pump()) == 1

    def test_full_batch_dispatches_immediately(self):
        clock = VClock()
        eng = FakeEngine(clock, batch_size=4, service_s=0.001)
        fe = ServingFrontend(eng, slo_s=1.0, admission="queue",
                             init_flush_s=0.001, clock=clock)
        r = _reqs(1, seed=12)[0]
        for _ in range(4):
            fe.try_submit(r.dense, r.idx, r.mask)
        assert len(fe.pump()) == 4

    def test_lookahead_stages_plans_for_peeked_requests(self):
        clock = VClock()
        eng = FakeEngine(clock, batch_size=4, service_s=0.001,
                         deferred=True)
        fe = ServingFrontend(eng, slo_s=1.0, admission="queue",
                             init_flush_s=0.001, clock=clock,
                             lookahead=True)
        r = _reqs(1, seed=13)[0]
        for _ in range(3):
            fe.try_submit(r.dense, r.idx, r.mask)
            fe.pump()
        assert fe.stats.plans_staged >= 1
        assert eng.staged and all(n <= 4 for n in eng.staged)


# ---------------------------------------------------------------------------
# traffic-fault builders + injector hook
# ---------------------------------------------------------------------------


class TestTrafficFaults:
    def test_arrival_burst_composes_multiplicatively(self):
        p = FaultPlan.none(2, 8).with_arrival_burst(2, 3, 4.0) \
            .with_arrival_burst(3, 2, 2.0)
        assert p.arrival_factor(1) == 1.0
        assert p.arrival_factor(2) == 4.0
        assert p.arrival_factor(3) == 8.0
        assert p.arrival_factor(4) == 8.0
        assert p.arrival_factor(5) == 1.0
        with pytest.raises(ValueError):
            p.with_arrival_burst(0, 1, 0.0)

    def test_queue_delay_windows_add(self):
        p = FaultPlan.none(2, 8).with_queue_delay(1, 2, 0.01) \
            .with_queue_delay(2, 2, 0.02)
        assert p.queue_delay_of(0) == 0.0
        assert p.queue_delay_of(1) == pytest.approx(0.01)
        assert p.queue_delay_of(2) == pytest.approx(0.03)
        assert p.queue_delay_of(3) == pytest.approx(0.02)
        # traffic faults do not make a plan non-transient (member regime)
        assert p.transient_only()

    def test_injector_on_dequeue_stalls_and_ledgers(self):
        p = FaultPlan.none(2, 4).with_queue_delay(1, 1, 0.003)
        inj = FaultInjector(p, time_scale=1.0)
        assert inj.on_dequeue(0) == 0.0
        d = inj.on_dequeue(1)
        assert d == pytest.approx(0.003)
        assert inj.injected_queue_delay_s == pytest.approx(0.003)

    def test_frontend_pays_the_injected_queue_delay(self):
        clock = VClock()
        eng = FakeEngine(clock, batch_size=2, service_s=0.001)
        plan = FaultPlan.none(1, 4).with_queue_delay(0, 4, 0.002)
        inj = FaultInjector(plan)
        fe = ServingFrontend(eng, slo_s=1.0, admission="queue",
                             init_flush_s=0.001, clock=clock, faults=inj)
        r = _reqs(1, seed=14)[0]
        for _ in range(2):
            fe.try_submit(r.dense, r.idx, r.mask)
        fe.pump()
        assert inj.injected_queue_delay_s > 0


# ---------------------------------------------------------------------------
# open-loop arrival generator
# ---------------------------------------------------------------------------


class TestArrivals:
    def test_deterministic_and_sorted(self):
        a = S.open_loop_arrivals(200, rate_rps=1000.0, burstiness=0.3,
                                 seed=5)
        b = S.open_loop_arrivals(200, rate_rps=1000.0, burstiness=0.3,
                                 seed=5)
        c = S.open_loop_arrivals(200, rate_rps=1000.0, burstiness=0.3,
                                 seed=6)
        assert np.array_equal(a, b)
        assert not np.array_equal(a, c)
        assert (np.diff(a) >= 0).all() and (a > 0).all()

    def test_burstiness_raises_gap_dispersion(self):
        smooth = S.open_loop_arrivals(2000, rate_rps=1000.0,
                                      burstiness=0.0, seed=1)
        bursty = S.open_loop_arrivals(2000, rate_rps=1000.0,
                                      burstiness=0.5, seed=1)
        def cv(t):
            g = np.diff(t)
            return g.std() / g.mean()
        assert cv(bursty) > cv(smooth)

    def test_fault_plan_burst_compresses_arrivals(self):
        plan = FaultPlan.none(1, 10).with_arrival_burst(1, 1, 50.0)
        base = S.open_loop_arrivals(300, rate_rps=1000.0, seed=2)
        f = S.open_loop_arrivals(
            300, rate_rps=1000.0, seed=2,
            factor_of=lambda i: plan.arrival_factor(i // 100))
        g0, gf = np.diff(base), np.diff(f)
        # the burst window's gaps shrink ~50x; outside it, identical
        assert np.allclose(gf[:99], g0[:99])
        assert gf[100:199].mean() < g0[100:199].mean() / 10
        assert np.allclose(gf[200:], g0[200:])

    def test_request_stream_shapes(self):
        from repro.configs.base import DLRMConfig
        cfg = DLRMConfig("t", table_sizes=(40, 60, 30), embed_dim=4,
                         n_dense_features=2, bottom_mlp=(4,),
                         top_mlp=(4, 1))
        reqs = S.request_stream(cfg, 10, rate_rps=100.0, t_pad=4, seed=0)
        assert len(reqs) == 10
        assert reqs[0].idx.shape == (4, cfg.max_hot)
        assert reqs[0].dense.shape == (2,)
        assert all(a.t_arrive <= b.t_arrive
                   for a, b in zip(reqs, reqs[1:]))


# ---------------------------------------------------------------------------
# real engine integration
# ---------------------------------------------------------------------------


def _real_engine(batch_size=16, **kw):
    import jax
    from repro.configs.base import DLRMConfig
    from repro.models import dlrm as D
    from repro.serving.engine import DLRMEngine
    cfg = DLRMConfig("t", table_sizes=(40, 60, 30, 50, 20, 70),
                     embed_dim=8, n_dense_features=4, bottom_mlp=(16, 8),
                     top_mlp=(16, 1), sparse_backend="ref")
    params = D.init_dlrm(jax.random.PRNGKey(0), cfg, n_shards=1)
    eng = DLRMEngine(params, cfg, batch_size=batch_size, bound=2,
                     microbatches=4, exchange="dense", **kw)
    return eng, cfg, params


class TestRealEngine:
    def test_admitted_ctrs_bit_identical_to_individual_flushes(self):
        eng, cfg, params = _real_engine()
        fe = ServingFrontend(eng, slo_s=10.0, admission="none",
                             shed=False, lookahead=False)
        reqs = S.request_stream(cfg, 48, rate_rps=1e6, seed=21)
        completed = []
        for r in reqs:
            fe.try_submit(r.dense, r.idx, r.mask)
            completed += fe.pump()
        completed += fe.drain()
        assert fe.stats.completed == 48 and fe.stats.accounted
        by_rid = {c.request_id: c.ctr for c in completed}
        # individually flushed oracle on a FRESH engine
        eng2, _, _ = _real_engine()
        for rid, r in enumerate(reqs):
            eng2.submit(r.dense, r.idx, r.mask)
            single = eng2.flush()
            assert single.shape == (1,)
            assert np.float64(single[0]) == by_rid[rid], \
                f"request {rid}: batched CTR != individually flushed CTR"

    def test_drain_is_idempotent_no_op_when_empty(self):
        for pp in (False, True):
            eng, cfg, _ = _real_engine(plan_pipeline=pp)
            assert eng.drain() is None and eng.drain() is None
            r = S.request_stream(cfg, 1, rate_rps=1.0, seed=1)[0]
            eng.submit(r.dense, r.idx, r.mask)
            out = eng.drain()
            assert out is not None and out.shape == (1,)
            assert eng.drain() is None        # second drain: clean no-op
            assert eng.flush() is None        # empty flush too

    def test_plan_stage_hit_on_matching_batch(self):
        eng, cfg, _ = _real_engine(batch_size=8, plan_pipeline=True)
        fe = ServingFrontend(eng, slo_s=10.0, admission="none",
                             shed=False, lookahead=True)
        # 20 = 2 full batches + a 4-request tail: the tail is peeked (and
        # its plan staged) by the pumps after the second dispatch, then
        # drain() dispatches EXACTLY that peeked set -> staged-plan hit
        reqs = S.request_stream(cfg, 20, rate_rps=1e6, seed=22)
        completed = []
        for r in reqs:
            fe.try_submit(r.dense, r.idx, r.mask)
            completed += fe.pump()
        completed += fe.drain()
        # lookahead staged plans for prospective batches, and at least
        # one later flush dispatched exactly that batch
        assert fe.stats.plans_staged >= 1
        assert eng.plan_stage_hits >= 1
        assert fe.stats.completed == 20 and fe.stats.accounted
        # staged-plan serving is bit-identical to inline planning
        eng2, _, _ = _real_engine(batch_size=8, plan_pipeline=True)
        outs = []
        for r in reqs:
            got = eng2.submit(r.dense, r.idx, r.mask)
            if got is not None:
                outs.append(got)
        tail = eng2.drain()
        if tail is not None:
            outs.append(tail)
        ref = np.concatenate(outs)
        got = np.asarray(sorted((c.request_id, c.ctr) for c in completed))
        assert np.array_equal(got[:, 1], ref.astype(np.float64))

    def test_engine_stats_to_dict_plain_json(self):
        eng, cfg, _ = _real_engine()
        r = S.request_stream(cfg, 16, rate_rps=1e6, seed=23)
        for q in r:
            eng.submit(q.dense, q.idx, q.mask)
        d = eng.stats.to_dict()
        js = json.loads(json.dumps(d))
        assert js["batches"] == 1 and js["requests"] == 16
        assert "throughput_rps" in js
        assert set(f.name for f in dataclasses.fields(ServeStats)) \
            <= set(js)


# ---------------------------------------------------------------------------
# per-tenant weighted-fair dequeue (deficit round-robin)
# ---------------------------------------------------------------------------


class TestWeightedFairness:
    def _fe(self, clock, *, weights, batch_size=8, **kw):
        eng = FakeEngine(clock, batch_size=batch_size, service_s=0.004)
        kw.setdefault("admission", "none")
        kw.setdefault("shed", False)
        return eng, ServingFrontend(eng, slo_s=10.0, clock=clock,
                                    tenant_weights=weights, **kw)

    def _submit(self, fe, tenant, n):
        r = next(iter(_reqs(1, seed=3)))
        for _ in range(n):
            assert fe.try_submit(r.dense, r.idx, r.mask,
                                 tenant=tenant).admitted

    def test_slot_shares_converge_to_weight_ratio(self):
        """Sustained contention between a weight-3 and a weight-1 tenant:
        every batch of 8 carries slots in the 3:1 ratio (6 vs 2)."""
        clock = VClock()
        eng, fe = self._fe(clock, weights={"a": 3, "b": 1})
        self._submit(fe, "a", 32)
        self._submit(fe, "b", 32)
        for _ in range(4):
            done = fe.pump()
            by = {t: sum(1 for c in done if c.tenant == t)
                  for t in ("a", "b")}
            assert by == {"a": 6, "b": 2}, by

    def test_fifo_preserved_within_each_tenant(self):
        clock = VClock()
        eng, fe = self._fe(clock, weights={"a": 2, "b": 1})
        self._submit(fe, "a", 20)
        self._submit(fe, "b", 20)
        done = []
        while fe.stats.queued:
            done += fe.pump()
        done += fe.drain()
        for t in ("a", "b"):
            rids = [c.request_id for c in done if c.tenant == t]
            assert rids == sorted(rids), t

    def test_light_tenant_never_starves(self):
        """A 10:1 weight ratio (quantum larger than the batch) still
        reaches the light tenant: the round-robin cursor rotates across
        batches, so within any two consecutive batches the light tenant
        lands at least one slot — starvation is bounded, never
        indefinite."""
        clock = VClock()
        eng, fe = self._fe(clock, weights={"heavy": 10, "light": 1},
                           batch_size=8)
        self._submit(fe, "heavy", 40)
        self._submit(fe, "light", 8)
        light_per_batch = []
        for _ in range(6):
            done = fe.pump()
            light_per_batch.append(
                sum(1 for c in done if c.tenant == "light"))
        for i in range(len(light_per_batch) - 1):
            assert light_per_batch[i] + light_per_batch[i + 1] >= 1, \
                (i, light_per_batch)

    def test_idle_tenant_banks_no_credit(self):
        """A tenant whose queue EMPTIES forfeits its deficit: coming back
        after sitting out rounds, it gets its fair share, not a burst of
        banked slots."""
        clock = VClock()
        eng, fe = self._fe(clock, weights={"a": 1, "b": 1})
        self._submit(fe, "a", 16)
        while fe.stats.queued:          # two all-"a" batches; "b" is idle
            fe.pump()
        self._submit(fe, "a", 8)
        self._submit(fe, "b", 8)
        done = fe.pump()
        by = {t: sum(1 for c in done if c.tenant == t) for t in ("a", "b")}
        assert by == {"a": 4, "b": 4}, by

    def test_single_tenant_drr_equals_global_fifo(self):
        """With one tenant the weighted queue degenerates to the global
        FIFO: identical completion order to the weights-None frontend
        under the same virtual-clock schedule."""
        orders = []
        for weights in (None, {"default": 2}):
            clock = VClock()
            eng = FakeEngine(clock, batch_size=8, service_s=0.004)
            fe = ServingFrontend(eng, slo_s=0.05, max_queue=24,
                                 admission="slo", init_flush_s=0.004,
                                 clock=clock, seed=1,
                                 tenant_weights=weights)
            completed, _ = drive(fe, clock, _reqs(200, seed=11))
            assert fe.stats.accounted
            orders.append([(c.request_id, c.ctr) for c in completed])
        assert orders[0] == orders[1]

    def test_conservation_invariant_with_weights_under_load(self):
        """The exact accounting invariant survives weighted multi-tenant
        traffic with admission + shedding active."""
        clock = VClock()
        eng = FakeEngine(clock, batch_size=8, service_s=0.004)
        fe = ServingFrontend(eng, slo_s=0.03, max_queue=16,
                             admission="slo", shed=True,
                             init_flush_s=0.004, clock=clock, seed=2,
                             tenant_weights={"a": 3, "b": 1},
                             default_weight=2)
        rng = np.random.default_rng(5)
        completed = []
        for i, r in enumerate(_reqs(300, seed=13)):
            if r.t_arrive > clock.t:
                clock.t = r.t_arrive
            fe.try_submit(r.dense, r.idx, r.mask,
                          tenant=str(rng.choice(["a", "b", "c"])))
            completed += fe.pump()
            assert fe.stats.accounted, "invariant broke mid-stream"
        completed += fe.drain()
        st = fe.stats
        assert st.queued == 0 and st.inflight == 0
        assert st.admitted == st.served + st.degraded_served + st.shed
        rids = [c.request_id for c in completed]
        assert len(rids) == len(set(rids)) == st.completed

    def test_shed_pass_reaches_every_tenant_queue(self):
        clock = VClock()
        eng, fe = self._fe(clock, weights={"a": 1, "b": 1}, shed=True)
        self._submit(fe, "a", 4)
        self._submit(fe, "b", 4)
        clock.advance(100.0)            # every queued deadline expires
        fe._observe_flush(0.004)
        done = fe.pump()
        assert done == [] and fe.stats.shed == 8
        assert fe.stats.accounted

    def test_invalid_weights_rejected(self):
        clock = VClock()
        eng = FakeEngine(clock)
        with pytest.raises(ValueError):
            ServingFrontend(eng, slo_s=1.0, clock=clock,
                            tenant_weights={"a": 0})
        with pytest.raises(ValueError):
            ServingFrontend(eng, slo_s=1.0, clock=clock,
                            tenant_weights={"a": 1}, default_weight=0)


def test_serve_example_frontend_smoke():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "serve_dlrm_bls.py"),
         "--frontend", "--batches", "2", "--batch-size", "32",
         "--bound", "1", "--microbatches", "2", "--open-requests", "96",
         "--overload", "2.0", "--burstiness", "0.4", "--slo-ms", "200"],
        env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "accounting" in r.stdout and "exact" in r.stdout
