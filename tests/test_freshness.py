"""Online embedding freshness (DESIGN.md §10): versioned row deltas over
the BLS wire with bounded staleness, atomic apply, and crash-safe
rollback — without stopping traffic.

The invariants under test:
  * **Bounded staleness** — ``versions_behind ≤ k_fresh`` at EVERY flush,
    swept property-style over the fault grid (update burst × updater
    straggler × crash mid-apply);
  * **Bit-exact convergence** — once the stream drains, the served tables
    equal the apply-all-up-front oracle byte for byte, no matter which
    faults fired on the way;
  * **Zero extra collectives** — the delta sub-blob rides the SAME fused
    buffer as the embedding payload: one all_to_all (mono) / P−1
    ppermutes (ring) in the jaxpr, deltas or not;
  * **Integrity** — a corrupted row is checksum-rejected and re-requested,
    never applied and never lost;
  * **Zero lost requests** — serving continues through every fault; each
    submitted request is answered exactly once.
"""
import os
import subprocess
import sys

import numpy as np

from repro.configs.base import DLRMConfig
from repro.data import synthetic as S
from repro.runtime.freshness import VersionLedger, row_checksum

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# row_checksum: the wire-integrity primitive
# ---------------------------------------------------------------------------


class TestRowChecksum:
    def test_detects_every_single_byte_flip(self):
        rng = np.random.default_rng(0)
        vec = rng.standard_normal(8).astype(np.float32)
        ref = row_checksum(vec, 123, 7)
        raw = vec.copy().view(np.uint8)
        for i in range(raw.size):
            for bit in (0x01, 0x80, 0x55):
                mut = raw.copy()
                mut[i] ^= bit
                got = row_checksum(mut.view(np.float32), 123, 7)
                assert got != ref, (i, bit)

    def test_identity_mixing_rejects_misdelivery(self):
        vec = np.arange(8, dtype=np.float32)
        ref = row_checksum(vec, 10, 3)
        assert row_checksum(vec, 11, 3) != ref    # wrong row
        assert row_checksum(vec, 10, 4) != ref    # wrong version

    def test_vectorized_equals_per_row(self):
        rng = np.random.default_rng(1)
        vecs = rng.standard_normal((5, 8)).astype(np.float32)
        gids = np.arange(5) * 17
        batch = row_checksum(vecs, gids, 2)
        for i in range(5):
            assert batch[i] == row_checksum(vecs[i], gids[i], 2)

    def test_deterministic_across_dtypes(self):
        v16 = np.arange(4, dtype=np.float16)
        assert row_checksum(v16, 0, 1) == row_checksum(v16.copy(), 0, 1)


# ---------------------------------------------------------------------------
# VersionLedger: the staleness gate's arithmetic
# ---------------------------------------------------------------------------


class TestVersionLedger:
    def test_gate_blocks_exactly_past_k(self):
        led = VersionLedger(2, np.array([3, 1, 3, 3], np.int64),
                            shipped_max=3)
        assert led.min_applied == 1
        assert led.versions_behind == 2
        assert led.may_ship(3)                 # 3 - 1 = 2 <= k
        assert not led.may_ship(4)             # fastest updater blocks

    def test_empty_ledger_is_fresh(self):
        led = VersionLedger(1, np.zeros(0, np.int64))
        assert led.versions_behind == 0 and led.may_ship(1)


# ---------------------------------------------------------------------------
# The synthetic delta source
# ---------------------------------------------------------------------------


_CFG = DLRMConfig("t", table_sizes=(40, 60, 30), embed_dim=8,
                  n_dense_features=4, bottom_mlp=(16, 8), top_mlp=(16, 1))


class TestDeltaSource:
    def test_deterministic_per_seed_and_version(self):
        a = S.make_delta_batch(_CFG, 3, rows_per_version=16, seed=5)
        b = S.make_delta_batch(_CFG, 3, rows_per_version=16, seed=5)
        c = S.make_delta_batch(_CFG, 4, rows_per_version=16, seed=5)
        assert np.array_equal(a.tab, b.tab) and np.array_equal(a.vec, b.vec)
        assert not np.array_equal(a.vec, c.vec)

    def test_rows_in_table_bounds_and_deduped(self):
        b = S.make_delta_batch(_CFG, 1, rows_per_version=64, seed=2)
        assert (b.tab >= 0).all() and (b.tab < 3).all()
        sizes = np.array(_CFG.table_sizes)[b.tab]
        assert (b.row >= 0).all() and (b.row < sizes).all()
        keys = b.tab.astype(np.int64) * 10 ** 6 + b.row
        assert len(np.unique(keys)) == len(keys)    # one write per row

    def test_stream_is_monotone(self):
        st = S.delta_stream(_CFG, rows_per_version=4, seed=1)
        versions = [next(st).version for _ in range(5)]
        assert versions == [1, 2, 3, 4, 5]


# ---------------------------------------------------------------------------
# End-to-end: the shared subprocess scaffold
# ---------------------------------------------------------------------------

_PREAMBLE = """
import itertools
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import DLRMConfig
from repro.models import dlrm as D
from repro.sharding import partition
from repro.data import synthetic as S
from repro.runtime import elastic
from repro.runtime.faults import FaultPlan, FaultInjector
from repro.runtime.freshness import FreshnessManager, oracle_tables
from repro.serving.engine import DLRMEngine

cfg = DLRMConfig('t', table_sizes=(40, 60, 30, 50, 20, 70), embed_dim=8,
                 n_dense_features=4, bottom_mlp=(16, 8), top_mlp=(16, 1),
                 sparse_backend='ref')
P = 4
B = 48                              # divides pre- AND post-evict geometry
N_VER = 6
mesh = elastic.make_mesh_from(jax.devices()[:P], model=P)
params = D.init_dlrm(jax.random.PRNGKey(0), cfg, n_shards=P)
t_pad = D.padded_tables(cfg, P)
batches = [S.make_batch(cfg, B, mode='powerlaw', t_pad=t_pad, seed=9,
                        step=s) for s in range(24)]
delta_batches = [S.make_delta_batch(cfg, v, rows_per_version=6, seed=3)
                 for v in range(1, N_VER + 1)]


def fresh_source():
    return itertools.islice(S.delta_stream(cfg, rows_per_version=6,
                                           seed=3), N_VER)


def run_serve(faults=None, n_flushes=16, **eng_kw):
    fm = FreshnessManager(fresh_source(), k_fresh=2, slice_cap=4,
                          versions_per_flush=1)
    eng = DLRMEngine(params, cfg, batch_size=B, bound=1, microbatches=2,
                     exchange='dense', freshness=fm, faults=faults,
                     retry_backoff_s=0.0, **eng_kw)
    outs = []
    with partition.axis_rules(mesh):
        for s in range(n_flushes):
            b = batches[s % len(batches)]
            for r in range(B):
                o = eng.submit(b.dense[r], b.idx[r], b.mask[r])
                if o is not None:
                    outs.append(o)
            if fm.fully_committed and s >= 4:
                break
    return eng, fm, outs


def check_oracle(eng, base_params):
    want = np.array(jax.device_get(
        oracle_tables(base_params['tables'], delta_batches)))
    got = np.array(jax.device_get(eng.params['tables']))
    for t, size in enumerate(cfg.table_sizes):
        assert np.array_equal(want[t, :size], got[t, :size]), \\
            f'table {t} diverged from the oracle'
"""


def test_clean_stream_invariant_and_bit_exact_convergence():
    """No faults: the stream drains while serving, versions_behind stays
    within k_fresh at every flush, every request is answered, and the
    final tables match the apply-all-up-front oracle bit for bit."""
    run_sub(_PREAMBLE + """
eng, fm, outs = run_serve()
n_flushes = len(outs)
assert all(v <= fm.k_fresh for v in fm.behind_trace), fm.behind_trace
assert fm.fully_committed, (len(fm._sendq), len(fm._apply_buf))
assert fm.rows_applied == sum(b.n_rows for b in delta_batches)
assert fm.delta_rejects == 0 and fm.rollbacks == 0
assert eng.stats.rows_applied == fm.rows_applied
assert eng.stats.versions_behind == 0
assert len(outs) * B == eng.stats.requests     # zero lost requests
assert all(np.isfinite(np.asarray(o)).all() for o in outs)
check_oracle(eng, params)
d = eng.stats.to_dict()
for k in ('rows_applied', 'rows_stale_served', 'versions_behind',
          'delta_rejects', 'apply_rollbacks'):
    assert k in d, k
print('ok')
""")


def test_fault_grid_staleness_invariant_property_sweep():
    """The acceptance sweep: every combination of update burst × updater
    straggler × crash mid-apply.  In all 8 cells serving never stops
    (zero requests lost), ``versions_behind ≤ k_fresh`` holds at every
    flush, and the post-recovery tables are bit-exact vs the oracle."""
    run_sub(_PREAMBLE + """
for burst, straggle, crash in itertools.product([0, 1], repeat=3):
    plan = FaultPlan.none(P, 32)
    if burst:
        plan = plan.with_update_burst(2, 2, 3.0)
    if straggle:
        plan = plan.with_updater_straggler(1, from_step=3, n_steps=3)
    if crash:
        plan = plan.with_apply_crash(2, at_step=4)
    eng, fm, outs = run_serve(faults=FaultInjector(plan, time_scale=0.0),
                              n_flushes=20)
    cell = (burst, straggle, crash)
    assert all(v <= fm.k_fresh for v in fm.behind_trace), \\
        (cell, fm.behind_trace)
    assert fm.fully_committed, (cell, len(fm._sendq), len(fm._apply_buf),
                                dict(fm._remaining))
    assert len(outs) * B == eng.stats.requests, cell   # zero lost
    if crash:
        assert fm.rollbacks >= 1 and eng.stats.evictions >= 1, cell
    if straggle:
        assert fm.source_blocked >= 0, cell
    check_oracle(eng, params)
print('ok')
""")


def test_corrupt_delta_checksum_rejected_then_reapplied():
    """A corrupted payload is rejected by the receiver-side checksum and
    RE-REQUESTED: the reject is ledgered, the row arrives clean on a
    later flush, and the final tables are still oracle-exact — a
    corrupted delta is a retried delta, never an applied-garbage or a
    lost one."""
    run_sub(_PREAMBLE + """
plan = FaultPlan.none(P, 32).with_delta_corruption(0, 1, n_rows=2) \\
                            .with_delta_corruption(2, 3, n_rows=1)
eng, fm, outs = run_serve(faults=FaultInjector(plan, time_scale=0.0),
                          n_flushes=20)
assert fm.delta_rejects >= 2, fm.delta_rejects
assert eng.stats.delta_rejects == fm.delta_rejects
assert fm.fully_committed
assert all(v <= fm.k_fresh for v in fm.behind_trace)
assert len(outs) * B == eng.stats.requests
check_oracle(eng, params)
print('ok')
""")


def test_crash_mid_apply_rolls_back_then_replays():
    """A crash INSIDE the apply window (after staging, before commit)
    leaves the serving tables on the previous version — the rollback is
    the absence of the swap — and PR 6's evict → replay recovery re-ships
    the buffered rows under the shrunken geometry."""
    run_sub(_PREAMBLE + """
plan = FaultPlan.none(P, 32).with_apply_crash(1, at_step=3)
eng, fm, outs = run_serve(faults=FaultInjector(plan, time_scale=0.0),
                          n_flushes=20)
assert fm.rollbacks == 1
assert eng.stats.apply_rollbacks == 1
assert eng.stats.evictions == 1 and eng.stats.replays >= 1
assert eng._mesh is not None and eng._mesh.shape['model'] == 3
assert fm.fully_committed
assert len(outs) * B == eng.stats.requests      # zero lost requests
check_oracle(eng, params)
print('ok')
""")


def test_degraded_member_serves_last_good_version():
    """A degraded member's rows stay buffered (it keeps serving its
    last-good version) while its lag holds the staleness gate; restoring
    it lets the stream drain to the oracle."""
    run_sub(_PREAMBLE + """
fm = FreshnessManager(fresh_source(), k_fresh=2, slice_cap=4)
eng = DLRMEngine(params, cfg, batch_size=B, bound=1, microbatches=2,
                 exchange='dense', freshness=fm)
with partition.axis_rules(mesh):
    eng.degrade((2,))
    for s in range(6):
        b = batches[s]
        for r in range(B):
            eng.submit(b.dense[r], b.idx[r], b.mask[r])
    held = [(v, g) for v, g in fm._apply_buf]
    own = [fm._owner(g, *fm._geometry(eng)[1:]) for _, g in held]
    assert held and set(own) == {2}, (held, own)   # only member 2 held
    assert all(v <= fm.k_fresh for v in fm.behind_trace)
    eng.degrade(())                                # member restored
    for s in range(6, 20):
        b = batches[s]
        for r in range(B):
            eng.submit(b.dense[r], b.idx[r], b.mask[r])
        if fm.fully_committed:
            break
assert fm.fully_committed
check_oracle(eng, params)
print('ok')
""")


def test_delta_wire_adds_zero_collectives_in_jaxpr():
    """The tentpole's wire contract, asserted from the jaxpr: WITH the
    delta sub-blob riding the fused buffer, a mono step still lowers to
    exactly one all_to_all and a ring step to exactly P−1 ppermutes —
    freshness costs zero extra collectives."""
    run_sub("""
import collections
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs.base import DLRMConfig
from repro.models import dlrm as D
from repro.data import synthetic as S
from repro.sharding import partition

def count_collectives(closed):
    c = collections.Counter()
    def walk(jx):
        for eqn in jx.eqns:
            c[eqn.primitive.name] += 1
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (tuple, list)) else [v]):
                    if hasattr(sub, "jaxpr"):
                        walk(sub.jaxpr)
                    elif hasattr(sub, "eqns"):
                        walk(sub)
    walk(closed.jaxpr)
    return c

cfg = DLRMConfig(name='t', table_sizes=(100, 50, 80, 60, 90, 40),
                 embed_dim=16, bottom_mlp=(32, 16), top_mlp=(32, 1),
                 max_hot=4)
mesh = compat.make_mesh((2, 4), ("data", "model"))
params = D.init_dlrm(jax.random.PRNGKey(0), cfg, n_shards=4)
b = S.make_batch(cfg, 64, mode='hetero', t_pad=D.padded_tables(cfg, 4),
                 seed=1)
dense, idx, mask = map(jnp.asarray, (b.dense, b.idx, b.mask))
P, mb, dcap, s = 4, 2, 4, 16
deltas = {
    'dcnt': jnp.zeros((P, mb, 1), jnp.int32),
    'dcs': jnp.zeros((P, mb, dcap), jnp.uint32),
    'dgid': jnp.zeros((P, mb, dcap), jnp.int32),
    'dvec': jnp.zeros((P, mb, dcap, s), jnp.float32),
    'dver': jnp.zeros((P, mb, 1), jnp.int32),
}
with partition.axis_rules(mesh):
    for pipe, want in [('mono', (1, 0)), ('ring', (0, 3))]:
        for dl in (None, deltas):
            jx = jax.make_jaxpr(
                lambda p, d, i, m, pipe=pipe, dl=dl:
                D.forward_distributed(p, cfg, d, i, m, microbatches=mb,
                                      exchange='dense',
                                      exchange_pipeline=pipe, deltas=dl)
                )(params, dense, idx, mask)
            c = count_collectives(jx)
            got = (c['all_to_all'], c['ppermute'])
            assert got == want, (pipe, dl is not None, dict(c))
print('ok')
""")


def test_freshness_refreshes_hot_cache_rows_in_place():
    """With a calibrated hot cache, a delta touching a cached row updates
    the CACHED copy in the same atomic window as the table — after drain
    every cached row equals its table row (no stale cache serving a
    fresh table)."""
    run_sub(_PREAMBLE + """
fm = FreshnessManager(fresh_source(), k_fresh=2, slice_cap=4)
eng = DLRMEngine(params, cfg, batch_size=B, bound=1, microbatches=2,
                 exchange='dense', freshness=fm)
with partition.axis_rules(mesh):
    b0 = batches[0]
    eng.calibrate_cache(b0.idx, b0.mask, cache_rows=16)
    for s in range(20):
        b = batches[s]
        for r in range(B):
            eng.submit(b.dense[r], b.idx[r], b.mask[r])
        if fm.fully_committed:
            break
assert fm.fully_committed
assert fm.cache_refreshed > 0, 'no cached row was touched by any delta'
assert eng.stats.rows_applied == sum(b.n_rows for b in delta_batches)
check_oracle(eng, params)
tables = np.array(jax.device_get(eng.params['tables']))
ids = np.array(jax.device_get(eng.cache.hot_ids))
rows = np.array(jax.device_get(eng.cache.hot_rows))
for t in range(ids.shape[0]):
    for c in range(ids.shape[1]):
        rid = ids[t, c]
        if rid >= 0:
            assert np.array_equal(rows[t, c], tables[t, rid]), (t, c, rid)
print('ok')
""")


def test_serve_example_updates_smoke():
    """examples/serve_dlrm_bls.py --frontend --updates: the demo serves an
    open-loop bursty stream WHILE a live delta stream rides the wire, and
    its own assertions (exact accounting + bounded staleness) hold."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples",
                                      "serve_dlrm_bls.py"),
         "--frontend", "--batches", "2", "--batch-size", "32",
         "--bound", "1", "--microbatches", "2", "--open-requests", "96",
         "--overload", "2.0", "--burstiness", "0.4", "--slo-ms", "200",
         "--updates", "4", "--k-fresh", "2"],
        env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "accounting" in r.stdout and "exact" in r.stdout
    assert "freshness: applied" in r.stdout, r.stdout
    assert "<= k_fresh 2" in r.stdout, r.stdout


def test_stale_serving_is_counted_exactly():
    """rows_stale_served counts (sample, table) bags that touched a row
    with a pending newer version — nonzero while versions are in flight
    under a hot (power-law) access pattern, and ledgered per flush."""
    run_sub(_PREAMBLE + """
fm = FreshnessManager(fresh_source(), k_fresh=2, slice_cap=2)
eng = DLRMEngine(params, cfg, batch_size=B, bound=1, microbatches=2,
                 exchange='dense', freshness=fm)
with partition.axis_rules(mesh):
    for s in range(20):
        b = batches[s]
        for r in range(B):
            eng.submit(b.dense[r], b.idx[r], b.mask[r])
        if fm.fully_committed:
            break
assert fm.fully_committed
assert eng.stats.rows_stale_served > 0     # slice_cap=2 keeps rows pending
print('ok')
""")
